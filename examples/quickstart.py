"""Quickstart: the paper's contribution in 60 seconds.

Builds a small workload of multi-stage jobs with early termination,
compares RANK (paper Eq. 23) against SERPT / SR (Gittins) / RANDOM /
OPTIMAL on the exact expected sojourn time of *successful* jobs, and
replays the worked example of paper §III-A.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.evaluator import evaluate, evaluate_many, optimal_order
from repro.core.jobs import JobSpec, generate_workload
from repro.core.policies import (
    ensure_cache_dir,
    erpt_values,
    rank_values,
    sr_rank_values,
)
from repro.obs import format_snapshot, get_registry, profiling


def worked_example():
    """Paper §III-A: two jobs where SR=10, SERPT=9.75, OPTIMAL=9.1."""
    jobs = [
        JobSpec(sizes=np.array([1.0, 10.0]), probs=np.array([0.25, 0.75])),
        JobSpec(sizes=np.array([3.0, 6.0]), probs=np.array([0.6, 0.4])),
    ]
    print("== Paper §III-A worked example ==")
    print(f"  SR (Gittins)      : {evaluate(jobs, 'sr'):.4f}   (paper: 10)")
    print(f"  SERPT             : {evaluate(jobs, 'serpt'):.4f} (paper: 9.75)")
    order, val = optimal_order(jobs)
    print(f"  OPTIMAL {order}   : {val:.4f}  (paper: 9.1)")
    print(f"  RANK values       : {rank_values(jobs)} -> job {np.argmin(rank_values(jobs))} first")


def random_workload():
    rng = np.random.default_rng(0)
    jobs = generate_workload(rng, n_jobs=7, num_stages=3, workload_set=1)
    print("\n== 7 random 3-stage jobs (workload set 1) ==")
    print(f"  rank  R(i) : {np.round(rank_values(jobs), 3)}")
    print(f"  ERPT       : {np.round(erpt_values(jobs), 3)}")
    print(f"  SR rank    : {np.round(sr_rank_values(jobs), 3)}")
    res = evaluate_many(jobs, ("optimal", "rank", "serpt", "sr", "random"), rng)
    print("  expected sojourn of successful jobs:")
    for k, v in sorted(res.items(), key=lambda kv: kv[1]):
        print(f"    {k:8s} {v:.4f}")


if __name__ == "__main__":
    ensure_cache_dir()  # persist workload tables across invocations
    profiling.enable()  # time the fused evaluator ops + cache tiers
    worked_example()
    random_workload()
    print()
    print(format_snapshot(get_registry().snapshot(), title="profiling"))
