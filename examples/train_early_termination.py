"""End-to-end driver: train a real model with checkpoint-based early
termination — the paper's job model running on the actual data plane.

A *stage* is ``--steps-per-stage`` optimizer steps; at each stage
boundary a metric gate checks training-loss improvement and terminates
unpromising jobs early (the paper's early termination), checkpointing
either way (fault tolerance).

Default is a ~1-minute CPU run on a reduced config.  ``--preset 100m``
trains a ~100M-parameter qwen3-style model for a few hundred steps (the
deliverable-scale run; expect hours on CPU, minutes on a real mesh).

Run:  PYTHONPATH=src python examples/train_early_termination.py
      PYTHONPATH=src python examples/train_early_termination.py --preset 100m --stages 4
"""

import argparse
import dataclasses
import tempfile

import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.registry import get_config, get_smoke
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.train import Trainer, default_plan


def make_cfg(preset: str):
    if preset == "tiny":
        return get_smoke("qwen3-1.7b")
    if preset == "100m":
        # ~100M params: qwen3 geometry scaled down
        return dataclasses.replace(
            get_config("qwen3-1.7b"),
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
            vocab_size=32768, attn_impl="xla", remat="none",
        )
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--steps-per-stage", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--min-improvement", type=float, default=0.005,
                    help="terminate early if per-stage loss drop is below this")
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"stages={args.stages} x {args.steps_per_stage} steps")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        ckpt = CheckpointManager(ckpt_dir, keep=2)
        plan = default_plan(cfg)
        trainer = Trainer(plan, data, ckpt, ckpt_every=args.steps_per_stage)

        stage_losses = []
        for stage in range(args.stages):
            _, _, hist = trainer.run(args.steps_per_stage, log_every=10)
            stage_losses.append(float(np.mean(hist[-5:])))
            print(f"[stage {stage}] loss={stage_losses[-1]:.4f} "
                  f"(ckpt at step {ckpt.latest_step()})")
            if len(stage_losses) >= 2:
                improvement = stage_losses[-2] - stage_losses[-1]
                if improvement < args.min_improvement:
                    print(f"[stage {stage}] EARLY TERMINATION: "
                          f"improvement {improvement:.4f} < {args.min_improvement}")
                    break
        else:
            print("job SUCCESSFUL: completed all stages")
        print(f"loss trajectory per stage: {np.round(stage_losses, 4)}")


if __name__ == "__main__":
    main()
