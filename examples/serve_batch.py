"""Batched serving demo: prefill + decode with KV caches on the real
serving path (same code the dry-run lowers at 32k/500k scale).

Loads a smoke-scale model, prefills a batch of prompts, then decodes new
tokens autoregressively — greedy sampling, per-request lengths, and a
consistency check against the full forward pass.

Run:  PYTHONPATH=src python examples/serve_batch.py --arch qwen3-1.7b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke
from repro.launch.serve import ServePlan, make_decode_fn, make_prefill_fn
from repro.models import transformer as T
from repro.parallel.sharding import DEFAULT_RULES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    max_len = args.prompt_len + args.gen_len
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    plan = ServePlan(cfg=cfg, mesh=None, rules=DEFAULT_RULES,
                     max_len=max_len, batch=args.batch)
    prefill = make_prefill_fn(plan)
    decode = make_decode_fn(plan)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    generated = [tok]
    t0 = time.perf_counter()
    for t in range(args.prompt_len, max_len - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(t))
        tok = jnp.argmax(logits[:, 0, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    t_decode = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)

    n_steps = len(generated) - 1
    print(f"model {cfg.name}: batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: {t_decode/max(n_steps,1)*1e3:.1f} ms/token")
    for b in range(args.batch):
        print(f"  req{b}: {np.asarray(out[b])[:12]} ...")

    # consistency: greedy decode must equal teacher-forced forward argmax
    full = jnp.concatenate([prompts, out[:, :1]], axis=1)
    x, _, _ = T.forward(params, {"tokens": full}, cfg, plan.ctx)
    from repro.models.layers import rms_norm, unembed

    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    lg = unembed(params["embed"], x, cfg, plan.ctx)
    want = jnp.argmax(lg[:, -2, : cfg.vocab_size], axis=-1)
    got = out[:, 0]
    assert bool(jnp.all(want == got)), (want, got)
    print("consistency vs forward pass: OK")


if __name__ == "__main__":
    main()
