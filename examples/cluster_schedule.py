"""End-to-end cluster demo: the paper's RANK policy gang-scheduling REAL
training jobs (tiny models, real jitted train steps) with early
termination, node failures and elastic scaling.

Each job is a reduced-config architecture from the assigned pool; a stage
runs actual optimizer steps, and the metric gate terminates jobs whose
loss stops improving — so the scheduler's size distributions come from
the jobs' stage history, and sojourn times are real wall-clock seconds.

Run:  PYTHONPATH=src python examples/cluster_schedule.py --jobs 6
"""

import argparse
import time

import numpy as np

from repro.cluster.faults import FaultConfig
from repro.cluster.manager import ClusterManager, TrainingJob
from repro.configs.registry import get_smoke
from repro.core import policies
from repro.core.jobs import JobSpec
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.train import Trainer, default_plan
from repro.obs import MetricsRegistry, TraceRecorder, format_snapshot

ARCH_POOL = ["qwen3-1.7b", "mamba2-1.3b", "mixtral-8x22b", "granite-3-8b",
             "llama3-8b", "jamba-v0.1-52b"]


def make_real_runner(arch: str, steps_per_stage: int, min_improvement: float):
    """A stage = real train steps on this host; gate on loss improvement."""
    cfg = get_smoke(arch)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=4))
    trainer = Trainer(default_plan(cfg), data, None)
    state = {"initialized": False, "last": np.inf}

    def runner(job: TrainingJob, stage: int):
        t0 = time.perf_counter()
        _, _, hist = trainer.run(steps_per_stage, log_every=0)
        wall = time.perf_counter() - t0
        loss = float(np.mean(hist[-3:]))
        improved = state["last"] - loss
        state["last"] = loss
        terminated = stage > 0 and improved < min_improvement
        return wall, terminated

    return runner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--steps-per-stage", type=int, default=5)
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--policy", default="rank", choices=["rank", "serpt", "sr", "fifo"])
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace JSON here")
    args = ap.parse_args()

    # index/duration tables for repeated runs persist across invocations
    policies.ensure_cache_dir()

    rng = np.random.default_rng(0)
    jobs = []
    for i in range(args.jobs):
        arch = ARCH_POOL[i % len(ARCH_POOL)]
        # size distribution from "historical stats": per-stage hazard ~ U(0.2, 0.5)
        hazards = rng.uniform(0.2, 0.5, args.stages - 1)
        probs, surv = [], 1.0
        for h in hazards:
            probs.append(surv * h)
            surv *= 1 - h
        probs.append(surv)
        sizes = np.cumsum(rng.uniform(2.0, 6.0, args.stages))
        spec = JobSpec(sizes=sizes, probs=np.array(probs), arrival=float(i) * 0.5,
                       job_id=i)
        jobs.append(TrainingJob(
            spec=spec, steps_per_stage=args.steps_per_stage,
            runner=make_real_runner(arch, args.steps_per_stage, 0.002),
            name=f"{arch}#{i}",
        ))

    print(f"scheduling {args.jobs} REAL training jobs on {args.servers} servers "
          f"({args.policy} policy)")
    cm = ClusterManager(
        jobs, args.servers, policy=args.policy, rng=rng,
        fault_cfg=FaultConfig(mtbf_hours=1e6),  # demo: no injected failures
    )
    metrics = MetricsRegistry()
    recorder = TraceRecorder()
    res = cm.run(recorder=recorder, metrics=metrics)
    print()
    print(format_snapshot(metrics.snapshot(), title=f"run metrics ({res.policy})"))
    for j in jobs:
        status = "SUCCESS" if j.success else f"terminated@stage{j.stage - 1}"
        print(f"  {j.name:22s} {status}")
    if args.trace_out:
        recorder.write_chrome_trace(args.trace_out)
        print(f"\nwrote {len(recorder)} trace records -> {args.trace_out} "
              "(load in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
