"""Checkpointing: async, atomic, sharded-pytree save/restore.

Fault-tolerance contract (used by the cluster manager and the trainer):

* **Atomicity** — checkpoints are staged into ``step_<k>.tmp`` and
  ``os.replace``d into place, so a node failure mid-save never corrupts
  the latest checkpoint.
* **Async** — device arrays are fetched to host (blocking only on the
  donated buffers) and written by a background thread, keeping I/O off
  the training critical path.  ``wait()`` joins before the next save or
  at exit.
* **Keep-K GC** — bounded disk footprint on long runs.
* **Self-describing** — the manifest stores the pytree structure, shapes
  and dtypes; ``restore`` rebuilds onto any target sharding (elastic
  restarts onto a different mesh re-shard via device_put).

Format: one ``.npz`` per checkpoint (single-host container); the layout
generalizes to per-process files keyed by shard index — the manifest
already records ``process_index``.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "/"


def _flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ----------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot ``tree`` (params/opt-state/host state) at ``step``."""
        self.wait()
        named = _flatten_with_names(tree)
        # fetch to host now (cheap for sharded arrays; frees device refs)
        host = {name: np.asarray(leaf) for name, leaf in named}
        manifest = {
            "step": int(step),
            "process_index": jax.process_index(),
            "leaves": {
                name: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for name, v in host.items()
            },
        }

        def _write():
            tmp = os.path.join(self.directory, f"step_{step}.tmp.npz")
            final = os.path.join(self.directory, f"step_{step}.npz")
            mtmp = os.path.join(self.directory, f"step_{step}.tmp.json")
            mfinal = os.path.join(self.directory, f"step_{step}.json")
            np.savez(tmp, **host)
            with open(mtmp, "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final)
            os.replace(mtmp, mfinal)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.directory, f"step_{s}{ext}"))
                except FileNotFoundError:
                    pass

    # -- restore ---------------------------------------------------------

    def all_steps(self) -> list[int]:
        steps = []
        for fn in os.listdir(self.directory):
            if fn.startswith("step_") and fn.endswith(".npz") and ".tmp" not in fn:
                steps.append(int(fn[len("step_") : -len(".npz")]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, step: int, target: Any, shardings: Any | None = None
    ) -> Any:
        """Load ``step`` onto the structure of ``target``.

        ``target`` supplies the pytree structure (leaves may be arrays or
        ShapeDtypeStructs); ``shardings`` (same structure or None) places
        each leaf — restarting on a different mesh reshards transparently.
        """
        self.wait()
        path = os.path.join(self.directory, f"step_{step}.npz")
        data = np.load(path)
        names = [n for n, _ in _flatten_with_names(target)]
        leaves = []
        flat_shard = (
            [s for _, s in _flatten_with_names(shardings)]
            if shardings is not None
            else [None] * len(names)
        )
        tgt_leaves = [leaf for _, leaf in _flatten_with_names(target)]
        for name, shard, tgt in zip(names, flat_shard, tgt_leaves):
            arr = data[name]
            want = np.dtype(tgt.dtype)
            if arr.dtype.kind == "V":  # npz stores bf16 etc. as raw void
                arr = arr.view(want)
            elif arr.dtype != want:
                arr = arr.astype(want)
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.device_put(arr))
        treedef = jax.tree.structure(target)
        return jax.tree.unflatten(treedef, leaves)
