"""Int8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound DP reductions).

Scheme: per-tensor symmetric int8 quantization of the local gradient plus
a persistent fp32 error-feedback residual (the quantization error is added
back before the next step's quantization), so compression noise is
momentum-like rather than biased.  ``compressed_psum`` runs the reduction
in int32 (sum of int8 lanes; exact for <= 2^23 summands) inside a
shard_map over the data axes, cutting all-reduce bytes 4× vs fp32 /
2× vs bf16.

This is opt-in (train.py --grad-compress): at (16, 16) scale the FSDP
reduce-scatter is rarely the bottleneck, but at 1000+ nodes with slower
inter-pod links it is (see DESIGN.md §7)."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from repro.parallel.sharding import shard_map  # version-compat shim
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["quantize", "dequantize", "ef_compress", "compressed_psum"]


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jax.Array, residual: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback quantization: returns (q, scale, new_residual)."""
    corrected = g.astype(jnp.float32) + residual
    q, scale = quantize(corrected)
    new_residual = corrected - dequantize(q, scale)
    return q, scale, new_residual


def compressed_psum(grads: Any, residuals: Any, mesh: Mesh, axes=("data",)) -> tuple[Any, Any]:
    """All-reduce-mean each gradient leaf in int8+scale with error feedback.

    grads/residuals are *replicated-layout* pytrees whose leaves are fully
    sharded over ``axes`` by GSPMD upstream; inside the shard_map each
    device quantizes its local shard, reduces int32 sums and max-scales.
    """
    axes = tuple(a for a in axes if a in mesh.axis_names)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def reduce_one(g, r):
        q, scale, new_r = ef_compress(g, r)
        # shared scale: use the max scale across devices so int sums align
        scale_max = jax.lax.pmax(scale, axes)
        q_rescaled = jnp.round(
            dequantize(q, scale) / scale_max
        ).astype(jnp.int32)
        total = jax.lax.psum(q_rescaled, axes)
        return (total.astype(jnp.float32) * scale_max / n).astype(g.dtype), new_r

    # leaves enter replicated per-device (already locally meaningful)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    spec_in = tuple(P() for _ in flat_g)

    def body(*flat):
        k = len(flat) // 2
        outs = [reduce_one(g, r) for g, r in zip(flat[:k], flat[k:])]
        return tuple(o[0] for o in outs) + tuple(o[1] for o in outs)

    outs = shard_map(
        body, mesh=mesh, in_specs=spec_in + spec_in,
        out_specs=spec_in + spec_in, check_rep=False,
    )(*flat_g, *flat_r)
    k = len(flat_g)
    return treedef.unflatten(outs[:k]), treedef.unflatten(outs[k:])
