from repro.optim.adamw import OptState, adafactor_init, adamw_init, apply_updates  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
