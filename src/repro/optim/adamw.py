"""Optimizers (hand-rolled; no optax in this container).

* AdamW with configurable moment dtype (fp32 default; bf16 halves
  optimizer-state HBM for the 1T-class models) and decoupled weight decay.
* Adafactor (factored second moment) for embedding-scale tensors where
  even bf16 moments are too expensive.
* Global-norm clipping, fused into the update.

Optimizer state is a pytree congruent with the params, so the FSDP
sharding rules of the parameters apply verbatim (ZeRO-3 for free) — the
launch code simply reuses each param's NamedSharding for its moments.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "OptConfig",
    "OptState",
    "adamw_init",
    "adafactor_init",
    "apply_updates",
    "global_norm",
]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4  # peak; schedules multiply this
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer HBM
    kind: str = "adamw"  # "adamw" | "adafactor"
    factored_min_size: int = 128  # adafactor: factor 2D tensors >= this


@dataclasses.dataclass
class OptState:
    step: jax.Array
    mu: Any  # first moment (adamw) | None entries (adafactor)
    nu: Any  # second moment | (row, col) factored pair


jax.tree_util.register_dataclass(
    OptState, data_fields=["step", "mu", "nu"], meta_fields=[]
)


def _moment_like(p, dtype):
    return jnp.zeros(p.shape, dtype)


def adamw_init(params: Any, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    mu = jax.tree.map(lambda p: _moment_like(p, dt), params)
    nu = jax.tree.map(lambda p: _moment_like(p, dt), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def _factorable(p, cfg: OptConfig) -> bool:
    return p.ndim >= 2 and min(p.shape[-2:]) >= cfg.factored_min_size


def adafactor_init(params: Any, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)

    def nu_of(p):
        if _factorable(p, cfg):
            return (
                jnp.zeros(p.shape[:-1], dt),  # row stats
                jnp.zeros(p.shape[:-2] + p.shape[-1:], dt),  # col stats
            )
        return _moment_like(p, dt)

    mu = jax.tree.map(lambda p: _moment_like(p, dt), params)  # keep momentum
    nu = jax.tree.map(nu_of, params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(
    params: Any,
    grads: Any,
    state: OptState,
    cfg: OptConfig,
    lr_scale: jax.Array | float = 1.0,
) -> tuple[Any, OptState]:
    """One optimizer step; returns (new_params, new_state)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = cfg.lr * lr_scale
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd_adamw(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mu_hat = mu_n / bc1
        nu_hat = nu_n / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(mu.dtype), nu_n.astype(nu.dtype)

    def upd_adafactor(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + 1e-30
        if isinstance(nu, tuple):
            r, c = nu
            r_n = b2 * r.astype(jnp.float32) + (1 - b2) * g2.mean(-1)
            c_n = b2 * c.astype(jnp.float32) + (1 - b2) * g2.mean(-2)
            denom = (
                r_n[..., None]
                * c_n[..., None, :]
                / jnp.maximum(r_n.mean(-1)[..., None, None], 1e-30)
            )
            nu_hat = denom / bc2
            nu_out = (r_n.astype(r.dtype), c_n.astype(c.dtype))
        else:
            nu_f = b2 * nu.astype(jnp.float32) + (1 - b2) * g2
            nu_hat = nu_f / bc2
            nu_out = nu_f.astype(nu.dtype)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        delta = (mu_n / bc1) * jax.lax.rsqrt(nu_hat + cfg.eps) + (
            cfg.weight_decay * p.astype(jnp.float32)
        )
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(mu.dtype), nu_out

    upd = upd_adamw if cfg.kind == "adamw" else upd_adafactor
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_mu, nu=new_nu)
