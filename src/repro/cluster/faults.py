"""Failure / straggler models for the cluster manager and the DES.

Per-node failures follow an exponential MTBF; at 1000+ nodes the fleet
failure rate is roughly (nodes / MTBF) per hour — e.g. 4k nodes at 30-day
MTBF ≈ 5.5 failures/hour, which is why checkpoint/restart and fast gang
rescheduling are first-class here (DESIGN.md §7).

Stragglers: a multiplicative slowdown drawn with probability
``straggler_prob`` per (job, stage) dispatch — the DES re-dispatches a
stage whose runtime exceeds ``deadline_factor`` × EWMA."""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FaultConfig", "FaultInjector"]


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    mtbf_hours: float = 24.0 * 30  # per node
    straggler_prob: float = 0.02
    straggler_slowdown: float = 4.0
    deadline_factor: float = 3.0
    restart_overhead: float = 60.0  # seconds to gang-restart from checkpoint


class FaultInjector:
    def __init__(self, cfg: FaultConfig, rng: np.random.Generator):
        self.cfg = cfg
        self.rng = rng

    def next_failure_time(self, now: float, n_nodes: int) -> float:
        """Time of the next node failure across a gang of n_nodes."""
        rate = n_nodes / (self.cfg.mtbf_hours * 3600.0)
        return now + float(self.rng.exponential(1.0 / max(rate, 1e-12)))

    def stage_runtime(self, nominal: float) -> tuple[float, bool]:
        """Possibly-straggled runtime for one dispatched stage."""
        if self.rng.uniform() < self.cfg.straggler_prob:
            return nominal * self.cfg.straggler_slowdown, True
        return nominal, False
