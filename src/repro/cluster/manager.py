"""Cluster manager: the paper's online RANK policy driving real training jobs.

This is the integration layer that makes the paper's contribution a
first-class framework feature:

* A :class:`TrainingJob` is a DNN training program with checkpoint-based
  early termination: a *stage* is ``steps_per_stage`` optimizer steps; at
  each stage boundary a metric gate (e.g. validation-loss plateau) decides
  whether the job continues — exactly the paper's multi-stage job model,
  with the size distribution estimated from historical jobs.
* The :class:`ClusterManager` is a discrete-event loop over W servers
  (mesh slices).  Scheduling follows the paper §V: jobs are held in a
  priority queue keyed by their *conditional rank* (Eq. 23 updated on
  survived stages); when a server finishes a stage, the served job
  competes with the queue head.
* Fault tolerance: per-node exponential failures abort the affected
  job's in-flight stage; the job resumes **the same stage** from its last
  checkpoint (plus restart overhead) — failures never advance or
  terminate a job (distinct from the paper's early termination).
* Straggler mitigation: a stage whose runtime exceeds
  ``deadline_factor × EWMA`` is re-dispatched (duplicate-and-race, the
  winner counts).
* Elastic scaling: ``resize(n_servers, at_time)`` events add/drain
  servers at stage boundaries; the rank order is slice-width invariant.

Jobs can be *simulated* (durations from the JobSpec — used for the
paper-scale studies) or *real* (a runner callback executes actual jitted
train steps on this host — used by examples/cluster_train_small.py).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable

import numpy as np

from repro.cluster.faults import FaultConfig, FaultInjector
from repro.core import policies
from repro.core.jobs import JobSpec

__all__ = ["TrainingJob", "ClusterManager", "ClusterResult"]


@dataclasses.dataclass
class TrainingJob:
    """A multi-stage job: spec for the scheduler + optional real runner."""

    spec: JobSpec
    steps_per_stage: int = 50
    # runner(job, stage_idx) -> (wall_seconds, terminated_early: bool)
    runner: Callable | None = None
    name: str = ""

    # runtime state (managed by ClusterManager)
    stage: int = 0
    completed: float = float("nan")
    success: bool = False
    restarts: int = 0
    straggler_redispatches: int = 0

    def realized_stop_stage(self, rng: np.random.Generator) -> int:
        if self.spec.outcome_stage >= 0:
            return self.spec.outcome_stage
        return int(rng.choice(self.spec.num_stages, p=self.spec.probs))


@dataclasses.dataclass
class ClusterResult:
    mean_sojourn_successful: float
    mean_sojourn_all: float
    n_success: int
    n_jobs: int
    makespan: float
    restarts: int
    straggler_redispatches: int
    policy: str


_ARRIVE, _STAGE_DONE, _FAILURE, _RESIZE = 0, 1, 2, 3


class ClusterManager:
    def __init__(
        self,
        jobs: list[TrainingJob],
        n_servers: int,
        policy: str = "rank",
        fault_cfg: FaultConfig | None = None,
        nodes_per_server: int = 1,
        rng: np.random.Generator | None = None,
        resize_events: list[tuple[float, int]] | None = None,
    ):
        self.jobs = jobs
        self.n_servers = n_servers
        self.policy = policy
        self.rng = rng or np.random.default_rng(0)
        self.faults = FaultInjector(fault_cfg, self.rng) if fault_cfg else None
        self.nodes_per_server = nodes_per_server
        self.resize_events = sorted(resize_events or [])
        specs = [j.spec for j in jobs]
        # Both tables come from the workload-keyed cache, so repeated
        # manager runs over the same workload (policy sweeps, fault-config
        # sweeps) reuse one computation.  _stage_durs is the padded (N, M)
        # increment matrix; stages >= num_stages are never dispatched.
        self.idx_table = policies.index_table(specs, policy)
        self._stage_durs = policies.stage_durations(specs)
        self._outcomes = np.array(
            [j.realized_stop_stage(self.rng) for j in jobs], dtype=np.int64
        )

    # -- event helpers ---------------------------------------------------

    def _stage_nominal(self, j: int, stage: int) -> float:
        job = self.jobs[j]
        if job.runner is not None:
            wall, terminated = job.runner(job, stage)
            # a real runner also overrides the realized outcome
            if terminated:
                self._outcomes[j] = min(stage, job.spec.num_stages - 1)
            return float(wall)
        return float(self._stage_durs[j][stage])

    def run(self) -> ClusterResult:
        jobs = self.jobs
        n = len(jobs)
        seq = itertools.count()
        events: list[tuple[float, int, int, int]] = [
            (j.spec.arrival, next(seq), _ARRIVE, i) for i, j in enumerate(jobs)
        ]
        for t, target in self.resize_events:
            events.append((t, next(seq), _RESIZE, target))
        heapq.heapify(events)

        ready: list[tuple[float, int, int]] = []  # (index, seq, job)
        free = self.n_servers
        target_servers = self.n_servers
        running: dict[int, int] = {}  # job -> dispatch epoch
        epoch = itertools.count()
        n_done = 0
        ewma = None
        makespan = 0.0
        completion = np.full(n, np.nan)

        if self.faults is not None:
            t_fail = self.faults.next_failure_time(0.0, self._total_nodes())
            heapq.heappush(events, (t_fail, next(seq), _FAILURE, -1))

        def dispatch(j: int, now: float):
            nonlocal ewma
            job = jobs[j]
            dur = self._stage_nominal(j, job.stage)
            if self.faults is not None:
                dur, straggled = self.faults.stage_runtime(dur)
                if ewma is not None and dur > self.faults.cfg.deadline_factor * ewma:
                    # duplicate-and-race: winner is the nominal re-dispatch
                    job.straggler_redispatches += 1
                    dur = min(dur, self._stage_nominal(j, job.stage))
            ewma = dur if ewma is None else 0.9 * ewma + 0.1 * dur
            ep = next(epoch)
            running[j] = ep
            heapq.heappush(events, (now + dur, next(seq), _STAGE_DONE, (j, ep)))

        def push_ready(j: int):
            heapq.heappush(
                ready, (float(self.idx_table[j, jobs[j].stage]), next(seq), j)
            )

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind != _FAILURE:  # an armed-but-idle failure timer is not work
                makespan = max(makespan, now)

            if kind == _ARRIVE:
                j = payload
                if free > 0:
                    free -= 1
                    dispatch(j, now)
                else:
                    push_ready(j)

            elif kind == _RESIZE:
                target_servers = payload
                grow = target_servers - (free + len(running))
                if grow > 0:
                    free += grow
                    while free > 0 and ready:
                        free -= 1
                        dispatch(heapq.heappop(ready)[2], now)
                # shrink: drain at stage boundaries (handled in _STAGE_DONE)

            elif kind == _FAILURE:
                # pick a random running job (gangs are node-disjoint)
                if running:
                    j = list(running.keys())[self.rng.integers(len(running))]
                    jobs[j].restarts += 1
                    # abort in-flight stage: re-dispatch same stage after
                    # restart overhead (checkpoint restore)
                    del running[j]
                    overhead = self.faults.cfg.restart_overhead
                    heapq.heappush(
                        events, (now + overhead, next(seq), _ARRIVE, j)
                    )
                    free += 1  # server freed during restore window
                    if ready and free > 0:
                        free -= 1
                        dispatch(heapq.heappop(ready)[2], now)
                if n_done < n:  # re-arm only while work remains
                    t_fail = self.faults.next_failure_time(now, self._total_nodes())
                    heapq.heappush(events, (t_fail, next(seq), _FAILURE, -1))

            else:  # _STAGE_DONE
                j, ep = payload
                if running.get(j) != ep:
                    continue  # stale event (job was failed/re-dispatched)
                del running[j]
                job = jobs[j]
                done_stage = job.stage
                job.stage += 1
                busy = len(running)
                if done_stage == self._outcomes[j]:  # job finished
                    completion[j] = now
                    job.completed = now
                    job.success = done_stage == job.spec.num_stages - 1
                    n_done += 1
                    if busy + free + 1 > target_servers:  # drain (shrink)
                        pass
                    elif ready:
                        dispatch(heapq.heappop(ready)[2], now)
                    else:
                        free += 1
                else:  # alive: compete with queue head (paper §V)
                    my_idx = float(self.idx_table[j, job.stage])
                    if ready and ready[0][0] < my_idx:
                        other = heapq.heappop(ready)[2]
                        push_ready(j)
                        dispatch(other, now)
                    else:
                        dispatch(j, now)

        arrivals = np.array([j.spec.arrival for j in jobs])
        success = np.array(
            [self._outcomes[i] == jobs[i].spec.num_stages - 1 for i in range(n)]
        )
        sojourn = completion - arrivals
        return ClusterResult(
            mean_sojourn_successful=float(sojourn[success].mean()) if success.any() else 0.0,
            mean_sojourn_all=float(np.nanmean(sojourn)),
            n_success=int(success.sum()),
            n_jobs=n,
            makespan=float(makespan),
            restarts=sum(j.restarts for j in jobs),
            straggler_redispatches=sum(j.straggler_redispatches for j in jobs),
            policy=self.policy,
        )

    def _total_nodes(self) -> int:
        return self.n_servers * self.nodes_per_server
