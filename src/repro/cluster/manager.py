"""Cluster manager: the paper's online RANK policy driving real training jobs.

This is the integration layer that makes the paper's contribution a
first-class framework feature:

* A :class:`TrainingJob` is a DNN training program with checkpoint-based
  early termination: a *stage* is ``steps_per_stage`` optimizer steps; at
  each stage boundary a metric gate (e.g. validation-loss plateau) decides
  whether the job continues — exactly the paper's multi-stage job model,
  with the size distribution estimated from historical jobs.
* Scheduling is the unified discrete-event engine
  (:mod:`repro.core.des.engine`, shared with ``core/simulator.py``):
  jobs are held in a priority queue keyed by their *conditional rank*
  (Eq. 23 updated on survived stages); same-instant events are drained
  as one batch before dispatch, so simultaneous arrivals contend by
  policy index, and a job finishing a stage re-competes with the whole
  queue at its new index (paper §V).
* Fault tolerance: per-node exponential failures abort the affected
  job's in-flight stage; the job resumes **the same stage** from its last
  checkpoint (plus restart overhead) — failures never advance or
  terminate a job (distinct from the paper's early termination).
* Straggler mitigation: a stage whose runtime exceeds
  ``deadline_factor × EWMA`` is re-dispatched (duplicate-and-race, the
  winner counts).
* Elastic scaling: ``resize(n_servers, at_time)`` events add/drain
  servers; grow is immediate, shrink retires idle servers immediately
  and busy ones at stage boundaries (including failure aborts), so
  ``len(running) + free <= target_servers`` holds at every event.

Jobs can be *simulated* (durations from the JobSpec — used for the
paper-scale studies) or *real* (a runner callback executes actual jitted
train steps on this host — used by examples/cluster_train_small.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.cluster.faults import FaultConfig, FaultInjector
from repro.core import policies
from repro.core.des import ARRIVAL, FAILURE, RESIZE, Engine, SchedulerHooks
from repro.core.jobs import JobSpec

__all__ = ["TrainingJob", "ClusterManager", "ClusterResult"]


@dataclasses.dataclass
class TrainingJob:
    """A multi-stage job: spec for the scheduler + optional real runner."""

    spec: JobSpec
    steps_per_stage: int = 50
    # runner(job, stage_idx) -> (wall_seconds, terminated_early: bool)
    runner: Callable | None = None
    name: str = ""

    # runtime state (managed by ClusterManager)
    stage: int = 0
    completed: float = float("nan")
    success: bool = False
    restarts: int = 0
    straggler_redispatches: int = 0

    def realized_stop_stage(self, rng: np.random.Generator) -> int:
        if self.spec.outcome_stage >= 0:
            return self.spec.outcome_stage
        return int(rng.choice(self.spec.num_stages, p=self.spec.probs))


@dataclasses.dataclass
class ClusterResult:
    mean_sojourn_successful: float
    mean_sojourn_all: float
    n_success: int
    n_jobs: int
    makespan: float
    restarts: int
    straggler_redispatches: int
    policy: str


class _ClusterHooks(SchedulerHooks):
    """Fault / straggler / real-runner behavior on top of the engine."""

    def __init__(self, mgr: "ClusterManager"):
        self.mgr = mgr
        self.ewma: float | None = None

    def index(self, job: int, stage: int) -> float:
        return float(self.mgr.idx_table[job, stage])

    def stage_duration(self, job: int, stage: int, now: float) -> float:
        mgr = self.mgr
        dur = mgr._stage_nominal(job, stage)
        if mgr.faults is not None:
            dur, straggled = mgr.faults.stage_runtime(dur)
            if self.ewma is not None and dur > mgr.faults.cfg.deadline_factor * self.ewma:
                # duplicate-and-race: winner is the nominal re-dispatch
                mgr.jobs[job].straggler_redispatches += 1
                dur = min(dur, mgr._stage_nominal(job, stage))
        self.ewma = dur if self.ewma is None else 0.9 * self.ewma + 0.1 * dur
        return dur

    def outcome(self, job: int) -> int:
        # read at stage-completion time: a real runner's metric gate may
        # have overridden the realized outcome while the stage ran
        return int(self.mgr._outcomes[job])

    def is_success(self, job: int) -> bool:
        mgr = self.mgr
        return bool(mgr._outcomes[job] == mgr.jobs[job].spec.num_stages - 1)

    def on_complete(self, job: int, now: float) -> None:
        tj = self.mgr.jobs[job]
        tj.completed = now
        tj.success = self.mgr._outcomes[job] == tj.spec.num_stages - 1

    def on_failure(self, engine: Engine, now: float) -> None:
        mgr = self.mgr
        if engine.pool.running:
            # pick a random running job (gangs are node-disjoint)
            job = list(engine.pool.running.keys())[mgr.rng.integers(engine.pool.busy)]
            mgr.jobs[job].restarts += 1
            # abort in-flight stage: the server frees (or drains, under a
            # shrink) during the checkpoint-restore window; the job
            # re-arrives at the same stage after the restart overhead
            engine.abort(job)
            engine.schedule(now + mgr.faults.cfg.restart_overhead, ARRIVAL, job)
        if engine.n_done < engine.n_jobs:  # re-arm only while work remains
            t_fail = mgr.faults.next_failure_time(now, mgr._total_nodes())
            engine.schedule(t_fail, FAILURE)


class ClusterManager:
    def __init__(
        self,
        jobs: list[TrainingJob],
        n_servers: int,
        policy: str = "rank",
        fault_cfg: FaultConfig | None = None,
        nodes_per_server: int = 1,
        rng: np.random.Generator | None = None,
        resize_events: list[tuple[float, int]] | None = None,
    ):
        self.jobs = jobs
        self.n_servers = n_servers
        self.policy = policy
        self.rng = rng or np.random.default_rng(0)
        self.faults = FaultInjector(fault_cfg, self.rng) if fault_cfg else None
        self.nodes_per_server = nodes_per_server
        self.resize_events = sorted(resize_events or [])
        specs = [j.spec for j in jobs]
        # Both tables come from the workload-keyed cache, so repeated
        # manager runs over the same workload (policy sweeps, fault-config
        # sweeps) reuse one computation.  _stage_durs is the padded (N, M)
        # increment matrix; stages >= num_stages are never dispatched.
        self.idx_table = policies.index_table(specs, policy)
        self._stage_durs = policies.stage_durations(specs)
        self._outcomes = np.array(
            [j.realized_stop_stage(self.rng) for j in jobs], dtype=np.int64
        )

    def _stage_nominal(self, j: int, stage: int) -> float:
        job = self.jobs[j]
        if job.runner is not None:
            wall, terminated = job.runner(job, stage)
            # a real runner also overrides the realized outcome
            if terminated:
                self._outcomes[j] = min(stage, job.spec.num_stages - 1)
            return float(wall)
        return float(self._stage_durs[j][stage])

    def run(self, observer=None, recorder=None, metrics=None) -> ClusterResult:
        """Schedule the jobs to completion; returns a :class:`ClusterResult`.

        Args:
          observer: deprecated bare callable ``observer(engine, now)``
            (per-event, unbatched); prefer ``recorder``.
          recorder: optional :class:`repro.obs.TraceRecorder` (or any
            :class:`~repro.core.des.events.EngineObserver`) receiving
            batched trace records; never changes scheduling results.
          metrics: optional :class:`repro.obs.MetricsRegistry` populated
            with the standard run metrics plus restart / straggler
            counters.
        """
        jobs = self.jobs
        n = len(jobs)
        eng = Engine(
            n, self.n_servers, _ClusterHooks(self), observer=[observer, recorder]
        )
        for i, j in enumerate(jobs):
            eng.schedule(j.spec.arrival, ARRIVAL, i)
        for t, target in self.resize_events:
            eng.schedule(t, RESIZE, target)
        if self.faults is not None:
            eng.schedule(self.faults.next_failure_time(0.0, self._total_nodes()), FAILURE)
        eng.run()

        for i, j in enumerate(jobs):  # expose per-job progress post-run
            j.stage = int(eng.stage[i])

        arrivals = np.array([j.spec.arrival for j in jobs])
        success = np.array(
            [self._outcomes[i] == jobs[i].spec.num_stages - 1 for i in range(n)]
        )
        sojourn = eng.completion - arrivals
        if metrics is not None:
            from repro.obs.metrics import record_run_metrics

            record_run_metrics(metrics, eng, arrivals, success)
            metrics.counter("jobs.restarts").inc(sum(j.restarts for j in jobs))
            metrics.counter("jobs.straggler_redispatches").inc(
                sum(j.straggler_redispatches for j in jobs)
            )
        return ClusterResult(
            mean_sojourn_successful=float(sojourn[success].mean()) if success.any() else 0.0,
            mean_sojourn_all=float(np.nanmean(sojourn)),
            n_success=int(success.sum()),
            n_jobs=n,
            makespan=float(eng.makespan),
            restarts=sum(j.restarts for j in jobs),
            straggler_redispatches=sum(j.straggler_redispatches for j in jobs),
            policy=self.policy,
        )

    def _total_nodes(self) -> int:
        return self.n_servers * self.nodes_per_server
