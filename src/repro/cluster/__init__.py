from repro.cluster.manager import ClusterManager, TrainingJob  # noqa: F401
from repro.cluster.faults import FaultInjector  # noqa: F401
