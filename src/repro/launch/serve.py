"""Serving: jitted prefill / decode step factories with cache sharding.

``make_prefill_fn``: (params, batch) -> (logits, cache) — the
inference-prefill program (logits for the prompt + the serving cache).

``make_decode_fn``: (params, token, cache, pos) -> (logits, cache) — one
new token against a seq_len cache; the cache is donated, so the compiled
program updates it in place.  For long-context (batch=1) cells the
``sp=True`` path shards the KV cache over the "data" axis and uses the
distributed LSE-combining decode attention.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.parallel.sharding import (
    AxisRules,
    ShardingCtx,
    logical_sharding,
    rules_for,
    shard_pytree_spec,
)

__all__ = ["ServePlan", "make_prefill_fn", "make_decode_fn"]


@dataclasses.dataclass(frozen=True)
class ServePlan:
    cfg: ModelConfig
    mesh: Any
    rules: AxisRules
    max_len: int
    batch: int
    sp: bool = False  # sequence-parallel cache (long-context decode)
    cache_rules: AxisRules | None = None  # cache-specific rules (decode batch)

    @property
    def ctx(self) -> ShardingCtx:
        return ShardingCtx(self.mesh, self.rules)

    def param_shardings(self):
        if self.mesh is None:
            return None
        return shard_pytree_spec(T.param_logical(self.cfg), self.mesh, self.rules)

    def cache_shardings(self):
        if self.mesh is None:
            return None
        logical = T.cache_logical(self.cfg)
        rules = self.cache_rules or self.rules
        return jax.tree.map(
            lambda log: logical_sharding(log, self.mesh, rules),
            logical,
            is_leaf=lambda x: isinstance(x, tuple),
        )


def default_serve_plan(
    cfg, mesh, shape_spec, *, long_context=False, tp_weights=False
) -> ServePlan:
    model_axis = (
        dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        if mesh is not None
        else 1
    )
    decode = shape_spec.kind == "decode" and not long_context
    rules = rules_for(
        cfg, long_context=long_context, decode_batch=decode, model_axis=model_axis
    )
    if tp_weights:
        from repro.parallel.sharding import serving_weight_rules

        rules = serving_weight_rules(rules)
        cache_rules = rules  # cache follows the TP-serving layout
    else:
        # the serving cache shards its batch over the full mesh: it is
        # the resident state (prefill emits it, decode carries it)
        cache_rules = rules_for(
            cfg, long_context=long_context, decode_batch=True, model_axis=model_axis
        )
    return ServePlan(
        cfg=cfg,
        mesh=mesh,
        rules=rules,
        max_len=shape_spec.seq_len,
        batch=shape_spec.global_batch,
        sp=long_context,
        cache_rules=cache_rules,
    )


def make_prefill_fn(plan: ServePlan) -> Callable:
    cfg, ctx = plan.cfg, plan.ctx

    def prefill_step(params, batch):
        return T.prefill(params, batch, cfg, ctx, max_len=plan.max_len)

    if plan.mesh is None:
        return jax.jit(prefill_step)
    pshard = plan.param_shardings()
    tok = logical_sharding(("batch", "seq"), plan.mesh, plan.rules)
    bshard = {"tokens": tok}
    if cfg.family == "encdec":
        bshard["enc_frames"] = logical_sharding(("batch", "seq", None), plan.mesh, plan.rules)
    if cfg.family == "vlm":
        bshard["image_embeds"] = logical_sharding(("batch", None, None), plan.mesh, plan.rules)
    return jax.jit(
        prefill_step,
        in_shardings=(pshard, bshard),
        out_shardings=(None, plan.cache_shardings()),
    )


def make_decode_fn(plan: ServePlan, with_memory: bool = False) -> Callable:
    """``with_memory``: encdec/vlm decode, which consumes the static cross
    K/V stack from ``prime_memory`` as an extra input."""
    cfg, ctx = plan.cfg, plan.ctx

    if with_memory:
        def decode(params, token, cache, pos, memory):
            return T.decode_step(
                params, token, cache, pos, cfg, ctx, memory=memory, sp=plan.sp
            )
    else:
        def decode(params, token, cache, pos):
            return T.decode_step(params, token, cache, pos, cfg, ctx, sp=plan.sp)

    if plan.mesh is None:
        return jax.jit(decode, donate_argnums=(2,))
    pshard = plan.param_shardings()
    cshard = plan.cache_shardings()
    tok = logical_sharding(("batch", None), plan.mesh, plan.rules)
    in_sh = [pshard, tok, cshard, None]
    if with_memory:
        mem_sh = logical_sharding(
            ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
            plan.mesh, plan.rules,
        )
        in_sh.append((mem_sh, mem_sh))
    return jax.jit(
        decode,
        in_shardings=tuple(in_sh),
        out_shardings=(None, cshard),
        donate_argnums=(2,),
    )
