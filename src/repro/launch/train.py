"""Trainer: jitted train_step factory with full sharding, plus a host-side
Trainer loop (data pipeline, checkpoint/restart, straggler watchdog) and a
CLI for local smoke-scale runs.

``make_train_step`` is the single source of truth for how a training
program is placed on a mesh — the dry-run, the examples, the cluster
manager and the real launcher all call it.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw as opt
from repro.optim.schedule import cosine_schedule
from repro.parallel.sharding import (
    AxisRules,
    ShardingCtx,
    logical_sharding,
    rules_for,
    shard_pytree_spec,
)

__all__ = ["TrainPlan", "make_train_step", "make_init", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    """Everything the launcher needs to place a training program."""

    cfg: ModelConfig
    opt_cfg: opt.OptConfig
    mesh: Any  # jax Mesh or None (single device)
    rules: AxisRules
    accum_steps: int = 1
    warmup_steps: int = 100
    total_steps: int = 10_000

    @property
    def ctx(self) -> ShardingCtx:
        return ShardingCtx(self.mesh, self.rules)

    # -- shardings -------------------------------------------------------

    def param_shardings(self):
        if self.mesh is None:
            return None
        return shard_pytree_spec(T.param_logical(self.cfg), self.mesh, self.rules)

    def opt_shardings(self, params_abstract):
        """Moments share their parameter's sharding (ZeRO-3 for free)."""
        if self.mesh is None:
            return None
        ps = self.param_shardings()

        def nu_shard(sh, p):
            return sh  # same-shape moments

        return opt.OptState(
            step=logical_sharding((), self.mesh, self.rules),
            mu=ps,
            nu=jax.tree.map(lambda s: s, ps),
        )

    def batch_shardings(self, batch_specs: dict):
        if self.mesh is None:
            return None
        return {
            k: logical_sharding(("batch", "seq"), self.mesh, self.rules)
            if v.ndim == 2
            else logical_sharding(("batch", "seq", None), self.mesh, self.rules)
            for k, v in batch_specs.items()
        }


def default_plan(
    cfg: ModelConfig, mesh=None, *, long_context: bool = False, **kw
) -> TrainPlan:
    model_axis = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1) if mesh is not None else 1
    rules = rules_for(cfg, long_context=long_context, model_axis=model_axis)
    moment_dtype = "bfloat16" if cfg.param_count() > 2e11 else "float32"
    opt_cfg = kw.pop("opt_cfg", None) or opt.OptConfig(moment_dtype=moment_dtype)
    return TrainPlan(cfg=cfg, opt_cfg=opt_cfg, mesh=mesh, rules=rules, **kw)


def make_init(plan: TrainPlan) -> Callable:
    """jitted (seed) -> (params, opt_state), placed per the plan."""
    cfg, mesh = plan.cfg, plan.mesh

    def init(key):
        params = T.init_params(cfg, key)
        state = (
            opt.adafactor_init(params, plan.opt_cfg)
            if plan.opt_cfg.kind == "adafactor"
            else opt.adamw_init(params, plan.opt_cfg)
        )
        return params, state

    if mesh is None:
        return jax.jit(init)
    pshard = plan.param_shardings()
    oshard = plan.opt_shardings(None)
    return jax.jit(init, out_shardings=(pshard, oshard))


def make_train_step(plan: TrainPlan) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics), jitted.

    Gradient accumulation: ``plan.accum_steps`` microbatches via lax.scan
    with fp32 grad accumulators (memory-term trade-off; see §Perf).
    """
    cfg = plan.cfg
    ctx = plan.ctx

    def loss_fn(params, batch):
        loss, metrics = T.lm_loss(params, batch, cfg, ctx)
        return loss, metrics

    def train_step(params, opt_state, batch):
        a = plan.accum_steps
        if a == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            def micro(carry, mb):
                g_acc, m_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32) / a, g_acc, g
                )
                m_acc = jax.tree.map(lambda x, y: x + y / a, m_acc, m)
                return (g_acc, m_acc), None

            micro_batch = jax.tree.map(
                lambda x: x.reshape(a, x.shape[0] // a, *x.shape[1:]), batch
            )
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"ce": 0.0, "aux": 0.0, "loss": 0.0}
            (grads, metrics), _ = jax.lax.scan(micro, (g0, m0), micro_batch)
            loss = metrics["loss"]

        lr_scale = cosine_schedule(
            opt_state.step, plan.warmup_steps, plan.total_steps
        )
        gnorm = opt.global_norm(grads)
        new_params, new_state = opt.apply_updates(
            params, grads, opt_state, plan.opt_cfg, lr_scale
        )
        metrics = dict(metrics, grad_norm=gnorm, lr_scale=lr_scale)
        return new_params, new_state, metrics

    if plan.mesh is None:
        return jax.jit(train_step, donate_argnums=(0, 1))

    if plan.opt_cfg.kind == "adafactor":
        raise NotImplementedError(
            "meshed adafactor shardings not wired; use adamw with "
            "moment_dtype=bfloat16 for the 1T-class configs"
        )
    pshard = plan.param_shardings()
    oshard = plan.opt_shardings(None)
    tok2d = logical_sharding(("batch", "seq"), plan.mesh, plan.rules)
    bshard = {"tokens": tok2d, "labels": tok2d}
    if cfg.family == "encdec":
        bshard["enc_frames"] = logical_sharding(("batch", "seq", None), plan.mesh, plan.rules)
    if cfg.family == "vlm":
        bshard["image_embeds"] = logical_sharding(("batch", None, None), plan.mesh, plan.rules)
    return jax.jit(
        train_step,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# Host-side trainer (smoke / example scale; cluster manager wraps this)
# ---------------------------------------------------------------------------


class Trainer:
    """Training loop with checkpoint/restart and a step-time watchdog.

    The watchdog implements single-job straggler mitigation: if a step
    exceeds ``straggler_factor`` × EWMA(step time), the step is flagged
    (in a real deployment this triggers slice re-dispatch; here it feeds
    the cluster manager's straggler policy)."""

    def __init__(
        self,
        plan: TrainPlan,
        data,
        ckpt_manager=None,
        ckpt_every: int = 100,
        straggler_factor: float = 3.0,
    ):
        self.plan = plan
        self.data = data
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.step_fn = make_train_step(plan)
        self._ewma = None
        self.straggler_events = 0

    def restore_or_init(self, seed: int = 0):
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            step = self.ckpt.latest_step()
            abstract = jax.eval_shape(
                lambda k: make_init(self.plan)(k), jax.random.PRNGKey(seed)
            )
            tree = self.ckpt.restore(step, {"params": abstract[0], "opt": abstract[1]})
            return tree["params"], tree["opt"], step
        params, state = make_init(self.plan)(jax.random.PRNGKey(seed))
        return params, state, 0

    def run(self, steps: int, seed: int = 0, log_every: int = 10, log=print):
        params, state, start = self.restore_or_init(seed)
        history = []
        for step in range(start, start + steps):
            batch = self.data.batch(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, state, metrics = self.step_fn(params, state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if self._ewma is None:
                self._ewma = dt
            elif dt > self.straggler_factor * self._ewma and step > start + 2:
                self.straggler_events += 1
            else:
                self._ewma = 0.9 * self._ewma + 0.1 * dt
            history.append(loss)
            if log_every and step % log_every == 0:
                log(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, {"params": params, "opt": state})
        if self.ckpt is not None:
            self.ckpt.save(start + steps, {"params": params, "opt": state}, blocking=True)
        return params, state, history


def main():
    ap = argparse.ArgumentParser(description="Local (smoke-scale) training run")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    from repro.configs.registry import get_smoke

    cfg = get_smoke(args.arch)
    plan = default_plan(cfg)
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    )
    ckpt = None
    if args.ckpt_dir:
        from repro.ckpt.checkpoint import CheckpointManager

        ckpt = CheckpointManager(args.ckpt_dir)
    trainer = Trainer(plan, data, ckpt)
    _, _, hist = trainer.run(args.steps)
    print(f"loss: {hist[0]:.4f} -> {hist[-1]:.4f}")


if __name__ == "__main__":
    main()
