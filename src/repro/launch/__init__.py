"""Launch plane: meshes, dry-run lowering, trainer/server entry points."""
