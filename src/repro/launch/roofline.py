"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

``cost_analysis()`` on this jax (0.8.2, CPU backend) reports per-device
flops/bytes for the SPMD-partitioned module (verified in
tests/test_dryrun.py), so the terms divide by per-chip peaks directly.

Collective bytes are NOT in cost_analysis: we parse the compiled HLO and
sum the *result* shapes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (the compiled module is the per-chip
program, so these are per-chip bytes on the wire; ragged-all-to-all and
fusion-wrapped variants are matched too).  For all-reduce the wire cost
is ~2× the buffer (reduce-scatter + all-gather phases of a ring); we
report both raw and ring-adjusted numbers.

Hardware constants (v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per direction, 4 links/chip but roofline uses the single-link
bottleneck convention from the assignment).
"""

from __future__ import annotations

import dataclasses
import json
import re


__all__ = [
    "HW",
    "collective_bytes",
    "roofline_terms",
    "RooflineReport",
]


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # bytes/s
    link_bw: float = 50e9  # bytes/s per ICI link


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[2,1024,512]{2,1,0} all-gather(...)
#       ROOT %t = (f32[8,128]{...}, f32[8,128]{...}) all-reduce(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\]{},]+)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-chip bytes moved by each collective kind (result-shape sums).

    async pairs (-start/-done) are counted once (on -start)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


def roofline_terms(
    flops_per_chip: float,
    bytes_per_chip: float,
    coll_bytes: dict[str, int],
    hw: Hardware = HW,
) -> dict:
    coll_total = sum(coll_bytes.values())
    # ring all-reduce moves ~2x the buffer; others ~1x
    coll_wire = coll_total + coll_bytes.get("all-reduce", 0)
    t_compute = flops_per_chip / hw.peak_flops
    t_memory = bytes_per_chip / hw.hbm_bw
    t_coll = coll_wire / hw.link_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        **terms,
        "dominant": dominant,
        "step_time_lower_bound": bound,
        "roofline_fraction": t_compute / bound if bound > 0 else 0.0,
        "collective_bytes": coll_bytes,
        "collective_wire_bytes": coll_wire,
    }


def model_flops(cfg, shape_spec, mode: str) -> float:
    """Analytic useful FLOPs: 6·N_active·tokens (train) / 2·N·tokens (fwd)."""
    n_active = cfg.param_count(active_only=True)
    if mode == "train":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape_spec.global_batch


@dataclasses.dataclass
class RooflineReport:
    """Aggregates per-cell dry-run JSONs into the §Roofline table."""

    rows: list[dict]

    @staticmethod
    def load(paths: list[str]) -> "RooflineReport":
        rows = []
        for p in paths:
            with open(p) as f:
                rows.append(json.load(f))
        rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
        return RooflineReport(rows)

    def to_markdown(self) -> str:
        hdr = (
            "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
            "| dominant | roofline frac | useful/HLO flops | HBM GiB/chip |\n"
            "|---|---|---|---|---|---|---|---|---|---|\n"
        )
        lines = []
        for r in self.rows:
            t = r["roofline"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {t['compute']*1e3:.2f} | {t['memory']*1e3:.2f} "
                f"| {t['collective']*1e3:.2f} | {t['dominant']} "
                f"| {t['roofline_fraction']:.2f} "
                f"| {r.get('useful_flops_ratio', float('nan')):.2f} "
                f"| {r.get('hbm_bytes_per_chip', 0)/2**30:.2f} |"
            )
        return hdr + "\n".join(lines)
