import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract the roofline inputs.

MUST be run as its own process (``python -m repro.launch.dryrun ...``):
the XLA_FLAGS line above executes before any jax import, giving this
process 512 placeholder CPU devices so ``jax.make_mesh`` can build the
(16,16) single-pod and (2,16,16) multi-pod meshes.  Nothing here
allocates real buffers — inputs are ShapeDtypeStructs and compilation is
AOT (``.lower().compile()``).

Artifacts: one JSON per cell under --out (default artifacts/dryrun/),
consumed by launch/roofline.py and EXPERIMENTS.md.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.shapes import SHAPES, arch_shape_config, input_specs, runnable_cells
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import default_serve_plan, make_decode_fn, make_prefill_fn
from repro.launch.train import default_plan, make_train_step
from repro.models import transformer as T


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if not isinstance(x, jax.ShapeDtypeStruct)
        else x,
        tree,
    )


def _sharded_bytes(abstract_tree, shardings, n_devices: int) -> int:
    """Per-chip bytes of a sharded pytree (parameters / opt state / cache)."""
    total = 0
    leaves = jax.tree.leaves(abstract_tree)
    shard_leaves = (
        jax.tree.leaves(shardings, is_leaf=lambda s: s is None)
        if shardings is not None
        else [None] * len(leaves)
    )
    for leaf, sh in zip(leaves, shard_leaves):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        nbytes = n * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
        if sh is not None and hasattr(sh, "shard_shape"):
            local = int(np.prod(sh.shard_shape(leaf.shape))) if leaf.shape else 1
            nbytes = local * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
        total += nbytes
    return total


def _depth_points(cfg) -> tuple[int, int, int]:
    """(L1, L2, period) for depth extrapolation (in layers)."""
    if cfg.family == "hybrid":
        p = cfg.attn_period
    elif cfg.family == "vlm":
        p = cfg.cross_attn_period
    else:
        p = 1
    return p, 2 * p, p


def _reduced(cfg, n_layers: int):
    kw = dict(n_layers=n_layers, scan_layers=False)
    if cfg.family == "encdec":
        kw["n_enc_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


SERVE_TP_WEIGHTS = os.environ.get("REPRO_SERVE_TP_WEIGHTS", "") == "1"


def lower_cell(arch: str, shape: str, multi_pod: bool, cfg=None, mesh=None):
    """Lower + compile one cell; returns (lowered, compiled, aux dict)."""
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    if cfg is None:
        cfg = arch_shape_config(arch, shape)
    spec = SHAPES[shape]
    specs = input_specs(arch, shape)
    long_context = shape == "long_500k"

    if spec.kind == "train":
        plan = default_plan(cfg, mesh)
        step = make_train_step(plan)
        params = T.abstract_params(cfg)
        from repro.optim import adamw as opt

        opt_state = jax.eval_shape(
            lambda p: opt.adamw_init(p, plan.opt_cfg), params
        )
        args = (params, opt_state, specs)
        state_shardings = (plan.param_shardings(), plan.opt_shardings(None))
        state_abstract = (params, opt_state)
    elif spec.kind == "prefill":
        plan = default_serve_plan(cfg, mesh, spec, tp_weights=SERVE_TP_WEIGHTS)
        step = make_prefill_fn(plan)
        params = T.abstract_params(cfg)
        batch = {k: v for k, v in specs.items()}
        args = (params, batch)
        state_shardings = (plan.param_shardings(),)
        state_abstract = (params,)
    else:  # decode
        plan = default_serve_plan(cfg, mesh, spec, long_context=long_context,
                                  tp_weights=SERVE_TP_WEIGHTS)
        with_memory = cfg.family in ("encdec", "vlm")
        step = make_decode_fn(plan, with_memory=with_memory)
        params = T.abstract_params(cfg)
        cache = _abstract(T.abstract_cache(cfg, spec.global_batch, spec.seq_len))
        args = [params, specs["token"], cache, specs["pos"]]
        if with_memory:
            s_mem = cfg.frontend_frames if cfg.family == "encdec" else cfg.num_image_tokens
            n_stack = (
                cfg.n_layers if cfg.family == "encdec"
                else cfg.n_layers // cfg.cross_attn_period
            )
            mem_shape = (n_stack, spec.global_batch, s_mem, cfg.n_kv_heads, cfg.hd)
            mem = jax.ShapeDtypeStruct(mem_shape, cfg.dtype)
            args.append((mem, mem))
        args = tuple(args)
        state_shardings = (plan.param_shardings(), plan.cache_shardings())
        state_abstract = (params, cache)

    t0 = time.perf_counter()
    lowered = step.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    n_dev = mesh.devices.size
    state_bytes = sum(
        _sharded_bytes(a, s, n_dev) for a, s in zip(state_abstract, state_shardings)
    )
    aux = {
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": int(n_dev),
        "t_lower_s": t_lower,
        "t_compile_s": t_compile,
        "state_bytes_per_chip": int(state_bytes),
        "kind": spec.kind,
        "cfg": cfg,
        "spec": spec,
    }
    return lowered, compiled, aux


def _cell_costs(compiled) -> tuple[float, float, dict]:
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = RL.collective_bytes(compiled.as_text())
    return flops, bytes_accessed, coll


def extrapolated_costs(arch, shape, multi_pod, base_cfg, mesh):
    """Per-chip flops/bytes/collective-bytes at full depth.

    XLA's cost analysis counts a while-loop (scan) body ONCE, so the full
    scanned program under-reports depth-dependent cost.  We compile two
    small UNROLLED programs (1 and 2 periods deep) with the real widths
    and shapes, and extrapolate linearly in depth — exact for
    depth-homogeneous stacks (all ours are).
    """
    l1, l2, period = _depth_points(base_cfg)
    f = {}
    for L in (l1, l2):
        cfg_r = _reduced(base_cfg, L)
        _, compiled, _ = lower_cell(arch, shape, multi_pod, cfg=cfg_r, mesh=mesh)
        f[L] = _cell_costs(compiled)
    n_per = (base_cfg.n_layers - l1) // period
    def ext(i, key=None):
        a = f[l1][i] if key is None else f[l1][i].get(key, 0)
        b = f[l2][i] if key is None else f[l2][i].get(key, 0)
        return a + (b - a) * n_per
    flops = ext(0)
    bytes_accessed = ext(1)
    coll = {k: int(ext(2, k)) for k in f[l1][2]}
    return flops, bytes_accessed, coll


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out[k] = v
    return out


def analyze_cell(arch: str, shape: str, multi_pod: bool, overrides=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    base_cfg = arch_shape_config(arch, shape)
    if overrides:
        base_cfg = dataclasses.replace(base_cfg, **overrides)
    lowered, compiled, aux = lower_cell(arch, shape, multi_pod, cfg=base_cfg, mesh=mesh)
    cfg, spec = aux.pop("cfg"), aux.pop("spec")

    # full-depth compiled artifact: memory picture + loop-body collectives
    raw_flops, raw_bytes, raw_coll = _cell_costs(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_fields = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception:  # CPU backend may not implement it
        mem_fields = {}

    # depth-extrapolated per-chip costs (see docstring)
    flops, bytes_accessed, coll = extrapolated_costs(
        arch, shape, multi_pod, base_cfg, mesh
    )
    roof = RL.roofline_terms(flops, bytes_accessed, coll)

    mflops = RL.model_flops(cfg, spec, spec.kind)
    n_dev = aux["n_devices"]
    useful_ratio = mflops / (flops * n_dev) if flops else float("nan")

    return {
        "arch": arch,
        "shape": shape,
        "overrides": overrides or {},
        **aux,
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": bytes_accessed,
        "raw_loop_counted_once": {
            "flops": raw_flops, "bytes": raw_bytes, "collectives": raw_coll,
        },
        "memory_analysis": mem_fields,
        "model_flops_total": mflops,
        "useful_flops_ratio": useful_ratio,
        "param_count": cfg.param_count(),
        "param_count_active": cfg.param_count(active_only=True),
        "roofline": roof,
        "hlo_collectives": coll,
        "hlo_bytes": len(compiled.as_text()),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    ap.add_argument(
        "--override", nargs="*", default=None, metavar="KEY=VAL",
        help="ModelConfig overrides for perf hillclimbs, e.g. remat=dots "
             "logit_chunk=8192 moe_group=4096",
    )
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()
    overrides = _parse_overrides(args.override)

    cells = runnable_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            if args.tag:
                tag += f"__{args.tag}"
            path = os.path.join(args.out, tag + ".json")
            try:
                t0 = time.perf_counter()
                row = analyze_cell(arch, shape, multi, overrides=overrides)
                with open(path, "w") as f:
                    json.dump(row, f, indent=1)
                r = row["roofline"]
                print(
                    f"[ok] {tag}: compile {row['t_compile_s']:.1f}s "
                    f"flops/chip {row['flops_per_chip']:.3e} "
                    f"dominant {r['dominant']} frac {r['roofline_fraction']:.2f} "
                    f"state {row['state_bytes_per_chip']/2**30:.2f} GiB/chip",
                    flush=True,
                )
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}", flush=True)
                traceback.print_exc()
                if args.fail_fast:
                    raise
    if failures:
        print(f"{len(failures)} failures:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print(f"all {len(cells) * len(meshes)} cells compiled OK")


if __name__ == "__main__":
    main()
