"""Production mesh construction.

A *function*, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first backend init —
the dry-run sets XLA_FLAGS before importing anything that calls into jax).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh", "POD_SHAPE", "MULTIPOD_SHAPE"]

POD_SHAPE = (16, 16)  # 256 chips per pod (v5e)
MULTIPOD_SHAPE = (2, 16, 16)  # 2 pods = 512 chips


def _auto(n: int):
    # pin current GSPMD semantics (jax 0.8 default changes in 0.9)
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The (data, model) single-pod mesh or (pod, data, model) 2-pod mesh."""
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"asked for {data}x{model} mesh but only {n} devices")
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
