"""Public grouped-GEMM MoE FFN op with impl dispatch + custom VJP.

Backward recomputes through the einsum reference (jax AD): the bwd is
three more grouped GEMMs and XLA emits them well; only the fwd path — the
one that runs twice under remat and dominates serving — gets the fused
Pallas kernel.  Validated against AD of the oracle in tests.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax

from repro.kernels.moe_gemm import kernel as K
from repro.kernels.moe_gemm.ref import moe_ffn_ref

__all__ = ["moe_ffn"]

Impl = Literal["auto", "xla", "pallas", "interpret"]


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _moe_pallas(x, wg, wu, wd, blocks, interpret):
    return K.moe_ffn_fwd(
        x, wg, wu, wd, block_c=blocks[0], block_f=blocks[1], interpret=interpret
    )


def _moe_fwd(x, wg, wu, wd, blocks, interpret):
    return _moe_pallas(x, wg, wu, wd, blocks, interpret), (x, wg, wu, wd)


def _moe_bwd(blocks, interpret, res, g):
    x, wg, wu, wd = res
    _, vjp = jax.vjp(moe_ffn_ref, x, wg, wu, wd)
    return vjp(g)


_moe_pallas.defvjp(_moe_fwd, _moe_bwd)


def moe_ffn(
    x: jax.Array,   # (E, Cap, Dm) dispatched tokens
    wg: jax.Array,  # (E, Dm, Dff)
    wu: jax.Array,
    wd: jax.Array,  # (E, Dff, Dm)
    *,
    impl: Impl = "auto",
    block_c: int = 128,
    block_f: int = 128,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "xla":
        return moe_ffn_ref(x, wg, wu, wd)
    return _moe_pallas(x, wg, wu, wd, (block_c, block_f), impl == "interpret")
