"""Per-expert SwiGLU FFN as a Pallas TPU kernel (grouped GEMM + fused act).

TPU adaptation: CUDA MoE kernels scatter tokens with warp-level routing;
on TPU the dispatch is a dense one-hot matmul done upstream (MXU-friendly)
and this kernel consumes the already-dispatched (E, Cap, Dm) buffer. The
win over plain XLA batched einsum is the *fusion*: gate/up GEMMs, SiLU,
elementwise product and the down GEMM run per (expert, token-block,
ff-block) tile without materializing the (E, Cap, Dff) activations in HBM
— at Dff=16 K (Mixtral) that intermediate is 8× the token buffer.

Grid: (E, n_cap, n_ff) — ff innermost; the f32 (bc, Dm) accumulator
lives in VMEM scratch across ff steps.  Tiles: bc×Dm + 2·(Dm×bf) +
bf×Dm + acc ≈ 128·6144·4B + 2·6144·128·2B + ... ≲ 10 MiB at the Mixtral
shape with (bc, bf) = (128, 128) — inside the v5e VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["moe_ffn_fwd"]


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nf):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # (bc, Dm)
    wg = wg_ref[0]  # (Dm, bf)
    wu = wu_ref[0]
    wd = wd_ref[0]  # (bf, Dm)
    h_g = jax.lax.dot_general(
        x, wg, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h_u = jax.lax.dot_general(
        x, wu, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    act = (jax.nn.silu(h_g) * h_u).astype(x.dtype)  # (bc, bf)
    acc_ref[...] += jax.lax.dot_general(
        act, wd, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(fi == nf - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_ffn_fwd(
    x: jax.Array,   # (E, Cap, Dm)
    wg: jax.Array,  # (E, Dm, Dff)
    wu: jax.Array,
    wd: jax.Array,  # (E, Dff, Dm)
    *,
    block_c: int = 128,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    e, cap, dm = x.shape
    dff = wg.shape[-1]
    bc = min(block_c, cap)
    bf = min(block_f, dff)
    if cap % bc or dff % bf:
        raise ValueError(f"cap {cap} / dff {dff} not divisible by ({bc},{bf})")
    nc, nf = cap // bc, dff // bf

    out = pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid=(e, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, dm), lambda e_, ci, fi: (e_, ci, 0)),
            pl.BlockSpec((1, dm, bf), lambda e_, ci, fi: (e_, 0, fi)),
            pl.BlockSpec((1, dm, bf), lambda e_, ci, fi: (e_, 0, fi)),
            pl.BlockSpec((1, bf, dm), lambda e_, ci, fi: (e_, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, dm), lambda e_, ci, fi: (e_, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((e, cap, dm), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, dm), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wd)
    return out
