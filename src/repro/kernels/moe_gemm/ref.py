"""Reference per-expert SwiGLU FFN over dispatched token buffers.

Input is the *dispatched* tensor (tokens already gathered into per-expert
capacity buffers by the router — see repro.models.moe): x (E, Cap, Dm).
Weights: wg/wu (E, Dm, Dff), wd (E, Dff, Dm).  Output (E, Cap, Dm).

This is the oracle and the XLA dispatch path (einsum batched over E —
XLA turns it into grouped GEMMs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_ffn_ref"]


def moe_ffn_ref(x, wg, wu, wd):
    h_g = jnp.einsum("ecd,edf->ecf", x, wg)
    h_u = jnp.einsum("ecd,edf->ecf", x, wu)
    act = jax.nn.silu(h_g.astype(jnp.float32)) * h_u.astype(jnp.float32)
    return jnp.einsum("ecf,efd->ecd", act.astype(x.dtype), wd)
