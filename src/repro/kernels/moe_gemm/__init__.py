from repro.kernels.moe_gemm.ops import moe_ffn  # noqa: F401
