"""Fused Pallas evaluator for E[sojourn time of successful jobs].

Kernel design note — mapping tiles to the paper's Eqs. (7)-(9)
===============================================================

The paper scores a static order exactly by summing over every outcome
combination ``c = (stage_0, ..., stage_{N-1})`` of which checkpoint each
job stops at:

* **Eq. (8)** — the probability of a combination is the product of the
  per-job stop probabilities, ``P(c) = prod_i p_{i, stage_i}``.
* **Eq. (7)** — given a combination with ``l >= 1`` successful jobs
  (``stage_i = M_i - 1``), the conditional objective is the *mean* of
  the successful jobs' completion times under the order's prefix sums.
* **Eq. (9)** — the expectation is the probability-weighted sum of
  Eq. (7) over all ``K = prod_i M_i`` combinations (``l = 0`` terms
  contribute zero).

The kernel grid is ``(P orders, ceil(K / BLOCK_COMBOS))`` with the
combination axis innermost (sequential on TPU).  Each grid tile owns
``BLOCK_COMBOS = 8 x 128`` combination *indices* shaped as one
``(SUBLANES, LANES)`` VPU tile and, per order position ``pos``:

1. decodes its slice of mixed-radix indices on the fly,
   ``stage = (k // stride_pos) % M_pos`` — the ``(K, N)`` outcome
   matrix of the seed implementation is never materialized anywhere;
2. gathers the realized duration and stop probability from the padded
   ``(N, M)`` size/probability tables via a one-hot select over the
   small stage axis (no vector gather needed on TPU);
3. advances the completion-time prefix sum ``t += d_pos`` (service
   position equals loop position because inputs are pre-permuted by the
   order), accumulating the Eq.-8 weight product ``w *= p`` and the
   Eq.-7 numerator/denominator (``tot += t`` on success, ``cnt += 1``);
4. accumulates ``w * tot / cnt`` — Eq. (9)'s summand — into a VMEM
   scratch accumulator that persists across combination tiles, flushed
   to the per-order output on the last tile.

A second kernel (``sojourn_outcomes``) runs the same fused gather +
prefix sum + weighted reduction over an *explicit* outcome matrix
(Monte-Carlo samples or a shared exact table) streamed through VMEM in
stage-major ``(SUBLANES, LANES)`` tiles.

``ops.sojourn_eval`` fronts both kernels with an ``impl`` dispatch
("pallas" / "interpret" / tiled "xla" streaming fallback for CPU), and
:mod:`repro.core.evaluator` rides it for ``expected_sojourn_static``,
Monte-Carlo evaluation, and ``optimal_order``.

Dynamic (stage-level) policies — SR / SERPT / conditional-RANK — stream
through the same scheme via :mod:`repro.kernels.sojourn_eval.dynamic`:
each tile decodes its combination indices with the identical mixed-radix
rule, then runs the single-server stage-boundary preemption simulation
*inside the tile*, selecting the minimum conditional index from the
policy's precomputed ``(N, M)`` rank table at every stage completion
(full design note in ``dynamic.py`` and ``docs/dynamic_sojourn_eval.md``).
``evaluator.expected_sojourn_dynamic`` rides it, which lifts exact
SR/SERPT evaluation from the materialized-table cap (2^21) to the same
2^26 streaming bound as static orders.

Beyond the exact cap, both ops take ``samples=(seed, n_samples)`` and
switch to *streaming Monte Carlo*: outcomes are generated inside the
tiles from a counter-based Threefry stream keyed by ``(seed, sample,
job)`` (:mod:`repro.kernels.sojourn_eval.rng`) and an inverse-CDF
search over the per-job stop-probability CDF, so no ``(S, N)`` sample
table is ever materialized and every policy evaluated under one seed
sees the identical outcome sequence (common random numbers; full design
note in ``docs/streaming_mc.md``).
"""

from repro.kernels.sojourn_eval.dynamic import dynamic_sojourn_mc  # noqa: F401

from repro.kernels.sojourn_eval.dynamic import sojourn_eval_dynamic  # noqa: F401
from repro.kernels.sojourn_eval.ops import sojourn_eval  # noqa: F401
