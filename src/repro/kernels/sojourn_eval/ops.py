"""Public fused sojourn-evaluation op with implementation dispatch.

``impl``:
  * "xla"       — tiled jit implementation: a ``lax.scan`` over
                  combination tiles decodes the mixed-radix indices on
                  the fly and accumulates the weighted reduction.  Same
                  streaming structure as the Pallas kernel (bounded
                  memory, no (K, N) host materialization); default on
                  CPU and the path the exact evaluator rides.
  * "pallas"    — the TPU Pallas kernels (compiled via Mosaic).
  * "interpret" — the Pallas kernels interpreted on CPU (parity tests).
  * "auto"      — "pallas" on TPU backends, else "xla".

Three entry modes, mirroring :mod:`repro.core.evaluator`'s sources of
outcome combinations:

* ``sojourn_eval(..., outcomes=None)`` — *exact enumeration*: evaluates
  all ``K = prod(M_i)`` combinations without ever materializing them
  (supports K up to ``repro.core.evaluator.MAX_EXACT_COMBOS``).
* ``sojourn_eval(..., outcomes=, weights=)`` — *explicit outcomes*:
  Monte-Carlo samples or a shared exact table; the float duration and
  success matrices of the seed path are never built host-side.
* ``sojourn_eval(..., samples=(seed, n_samples))`` — *streaming Monte
  Carlo*: outcomes are generated inside the tiles from the counter-based
  Threefry stream (:mod:`repro.kernels.sojourn_eval.rng`) and an
  inverse-CDF search, so no ``(S, N)`` sample table exists on host or
  device and sample counts are compute-bound rather than
  table-memory-bound.  The stream is keyed by original job id: every
  order/policy evaluated under one seed sees identical outcomes
  (common random numbers), and ``ref.ref_mc_outcomes`` replays the
  stream host-side bitwise for parity.

Precision follows the ambient JAX x64 mode: the evaluator calls this op
under ``jax.experimental.enable_x64`` so everything accumulates in
float64 (<=1e-9 parity with the seed path); on TPU the compiled kernels
run in float32.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sojourn_eval import kernel as K
from repro.kernels.sojourn_eval import rng
from repro.kernels.sojourn_eval.ref import mixed_radix_strides
from repro.obs import profiling

__all__ = ["sojourn_eval"]

Impl = Literal["auto", "xla", "pallas", "interpret"]

#: Combination indices per XLA scan tile (bounded-memory streaming).
XLA_TILE = 1 << 15
#: Soft cap on bytes of per-tile intermediates in the XLA path.
_TILE_BYTES_BUDGET = 256 << 20


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("xla", "pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}; options: auto/xla/pallas/interpret")
    return impl


def _order_batch(n_orders: int, tile: int, n: int) -> int:
    """Orders per jit call so (P_b, tile, N) intermediates stay bounded."""
    per_order = tile * n * 8  # float64 worst case
    return max(1, min(n_orders, 4096, _TILE_BYTES_BUDGET // max(per_order, 1)))


# ---------------------------------------------------------------------------
# XLA streaming implementation (shared decode across the order batch)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("strides", "radix", "k_total", "tile")
)
def _enum_xla(sizes, probs, orders, *, strides, radix, k_total, tile):
    """Exact fused evaluation; ``strides``/``radix`` are static tuples so
    the mixed-radix decode lowers to constant div/mod chains."""
    n = orders.shape[1]
    strides_a = jnp.asarray(strides, jnp.int32)[None, :]
    radix_a = jnp.asarray(radix, jnp.int32)[None, :]
    job_ids = jnp.arange(n, dtype=jnp.int32)[None, :]
    n_tiles = max(1, -(-k_total // tile))

    def tile_fn(carry, t):
        e_succ, e_all = carry
        k = t * tile + jnp.arange(tile, dtype=jnp.int32)
        valid = k < k_total
        s = (k[:, None] // strides_a) % radix_a  # (T, N) on-the-fly decode
        w = jnp.prod(probs[job_ids, s], axis=1) * valid  # Eq. (8)
        d = sizes[job_ids, s]  # (T, N) realized durations
        succ = s == radix_a - 1
        cnt = jnp.sum(succ, axis=1)  # order-invariant success count
        inv_cnt = jnp.where(cnt > 0, 1.0 / jnp.maximum(cnt, 1), 0.0)

        def per_order(order):
            tcum = jnp.cumsum(jnp.take(d, order, axis=1), axis=1)
            tot = jnp.sum(tcum * jnp.take(succ, order, axis=1), axis=1)
            return (
                jnp.dot(w, tot * inv_cnt),  # Eqs. (7)+(9)
                jnp.dot(w, jnp.mean(tcum, axis=1)),
            )

        des, dea = jax.vmap(per_order)(orders)
        return (e_succ + des, e_all + dea), None

    zeros = jnp.zeros((orders.shape[0],), sizes.dtype)
    (e_succ, e_all), _ = jax.lax.scan(
        tile_fn, (zeros, zeros), jnp.arange(n_tiles, dtype=jnp.int32)
    )
    return e_succ, e_all


@functools.partial(jax.jit, static_argnames=("n_samples", "tile"))
def _mc_xla(sizes, cdf, num_stages, orders, key2, *, n_samples, tile):
    """Streamed-MC fused evaluation: per-tile Threefry outcome generation
    with the same inverse-CDF count as the host replay, then the shared
    prefix-sum reduction.  ``key2`` is a (2,) uint32 array (traced, so
    sweeps over seeds do not recompile)."""
    n = orders.shape[1]
    job_ids = jnp.arange(n, dtype=jnp.int32)[None, :]
    n_tiles = max(1, -(-n_samples // tile))
    x1 = jnp.broadcast_to(job_ids, (tile, n)).astype(jnp.uint32)

    def tile_fn(carry, t):
        e_succ, e_all = carry
        k = t * tile + jnp.arange(tile, dtype=jnp.int32)
        x0 = jnp.broadcast_to(k[:, None], (tile, n)).astype(jnp.uint32)
        bits, _ = rng.threefry2x32(jnp, (key2[0], key2[1]), x0, x1)
        u = rng.uniform_from_bits(bits, sizes.dtype)
        s = jnp.minimum(
            jnp.sum(u[:, :, None] >= cdf[None, :, :], axis=2).astype(jnp.int32),
            num_stages[None, :] - 1,
        )
        w = (k < n_samples).astype(sizes.dtype) * (1.0 / n_samples)
        d = sizes[job_ids, s]  # (T, N) realized durations
        succ = s == num_stages[None, :] - 1
        cnt = jnp.sum(succ, axis=1)
        inv_cnt = jnp.where(cnt > 0, 1.0 / jnp.maximum(cnt, 1), 0.0)

        def per_order(order):
            tcum = jnp.cumsum(jnp.take(d, order, axis=1), axis=1)
            tot = jnp.sum(tcum * jnp.take(succ, order, axis=1), axis=1)
            return (
                jnp.dot(w, tot * inv_cnt),
                jnp.dot(w, jnp.mean(tcum, axis=1)),
            )

        des, dea = jax.vmap(per_order)(orders)
        return (e_succ + des, e_all + dea), None

    zeros = jnp.zeros((orders.shape[0],), sizes.dtype)
    (e_succ, e_all), _ = jax.lax.scan(
        tile_fn, (zeros, zeros), jnp.arange(n_tiles, dtype=jnp.int32)
    )
    return e_succ, e_all


@jax.jit
def _outcomes_xla(sizes, num_stages, outcomes, weights, orders):
    """Fused evaluation over an explicit outcome matrix: the duration and
    success gathers happen on-device instead of as host fancy-indexing."""
    n = orders.shape[1]
    job_ids = jnp.arange(n, dtype=jnp.int32)[None, :]
    d = sizes[job_ids, outcomes]  # (K, N)
    succ = outcomes == num_stages[None, :] - 1
    cnt = jnp.sum(succ, axis=1)
    inv_cnt = jnp.where(cnt > 0, 1.0 / jnp.maximum(cnt, 1), 0.0)

    def per_order(order):
        tcum = jnp.cumsum(jnp.take(d, order, axis=1), axis=1)
        tot = jnp.sum(tcum * jnp.take(succ, order, axis=1), axis=1)
        return (
            jnp.dot(weights, tot * inv_cnt),
            jnp.dot(weights, jnp.mean(tcum, axis=1)),
        )

    return jax.vmap(per_order)(orders)


# ---------------------------------------------------------------------------
# Pallas-path input preparation
# ---------------------------------------------------------------------------


def _permuted(arrs, orders_b):
    """Take the job axis of each array along every order in the batch."""
    return [np.take(a, orders_b, axis=0) for a in arrs]


def _tile_outcomes(outcomes, weights):
    """(K, N) -> (N, KT, SUBLANES, LANES) stage tiles + zero-padded weights."""
    k_total, n = outcomes.shape
    bk = K.BLOCK_COMBOS
    nkt = max(1, -(-k_total // bk))
    pad = nkt * bk - k_total
    oc = np.pad(outcomes.astype(np.int32), ((0, pad), (0, 0)))
    wt = np.pad(np.asarray(weights), (0, pad))
    oc_t = oc.T.reshape(n, nkt, K.SUBLANES, K.LANES)
    wt_t = wt.reshape(nkt, K.SUBLANES, K.LANES)
    return oc_t, wt_t


# ---------------------------------------------------------------------------
# Public op
# ---------------------------------------------------------------------------


def sojourn_eval(
    sizes: np.ndarray,  # (N, M) padded cumulative sizes
    probs: np.ndarray,  # (N, M) padded stop probabilities
    num_stages: np.ndarray,  # (N,) stage counts
    orders: np.ndarray,  # (P, N) static orders
    *,
    outcomes: np.ndarray | None = None,  # optional (K, N) explicit outcomes
    weights: np.ndarray | None = None,  # (K,) weights (required with outcomes)
    samples: tuple[int, int] | None = None,  # (seed, n_samples) streamed MC
    impl: Impl = "auto",
) -> tuple[np.ndarray, np.ndarray]:
    """(E[sojourn successful], E[sojourn all]) per order; see module doc.

    When :mod:`repro.obs.profiling` is enabled, each call is timed into
    a ``prof.sojourn_eval.static.<mode>.<impl>.seconds`` span (the
    numpy conversions inside synchronize the device work, so the span
    is end-to-end wall clock).
    """
    impl = _resolve(impl)
    mode = "mc" if samples is not None else (
        "enum" if outcomes is None else "outcomes"
    )
    with profiling.span(f"sojourn_eval.static.{mode}.{impl}"):
        return _sojourn_eval(
            sizes, probs, num_stages, orders,
            outcomes=outcomes, weights=weights, samples=samples, impl=impl,
        )


def _sojourn_eval(
    sizes, probs, num_stages, orders, *,
    outcomes=None, weights=None, samples=None, impl="xla",
) -> tuple[np.ndarray, np.ndarray]:
    if samples is not None and outcomes is not None:
        raise ValueError("samples= and outcomes= are mutually exclusive")
    sizes = np.asarray(sizes)
    probs = np.asarray(probs)
    num_stages = np.asarray(num_stages, dtype=np.int64)
    orders = np.asarray(orders, dtype=np.int32)
    n = sizes.shape[0]
    if orders.ndim != 2 or orders.shape[1] != n:
        raise ValueError(f"orders must be (P, {n}); got {orders.shape}")
    strides = mixed_radix_strides(num_stages)
    fdt = jnp.asarray(sizes).dtype  # f64 under x64, else f32
    sizes_j = jnp.asarray(sizes, fdt)
    probs_j = jnp.asarray(probs, fdt)

    interpret = impl == "interpret"
    e_succ_parts, e_all_parts = [], []
    if samples is not None:
        seed, n_samples = int(samples[0]), int(samples[1])
        if n_samples <= 0:
            raise ValueError(f"n_samples must be positive; got {n_samples}")
        cdf = np.cumsum(probs, axis=1)  # padded stages add 0 mass
        tile = min(
            XLA_TILE, max(K.BLOCK_COMBOS, 1 << (n_samples - 1).bit_length())
        )
        pb = _order_batch(orders.shape[0], tile, n)
        key2 = jnp.asarray(rng.split_seed(seed), jnp.uint32)
        for lo in range(0, orders.shape[0], pb):
            ob = orders[lo : lo + pb]
            if impl == "xla":
                es, ea = _mc_xla(
                    sizes_j,
                    jnp.asarray(cdf, fdt),
                    jnp.asarray(num_stages, jnp.int32),
                    jnp.asarray(ob),
                    key2,
                    n_samples=n_samples,
                    tile=tile,
                )
            else:
                sz_p, cdf_p, rx_p = _permuted(
                    [sizes, cdf, num_stages.astype(np.int32)], ob
                )
                es, ea = K.sojourn_mc(
                    jnp.asarray(sz_p, fdt),
                    jnp.asarray(cdf_p, fdt),
                    jnp.asarray(rx_p),
                    jnp.asarray(ob),
                    seed,
                    n_samples,
                    interpret=interpret,
                )
            e_succ_parts.append(np.asarray(es))
            e_all_parts.append(np.asarray(ea))
    elif outcomes is None:
        k_total = int(np.prod(num_stages, dtype=np.int64))
        tile = min(XLA_TILE, max(K.BLOCK_COMBOS, 1 << (k_total - 1).bit_length()))
        pb = _order_batch(orders.shape[0], tile, n)
        for lo in range(0, orders.shape[0], pb):
            ob = orders[lo : lo + pb]
            if impl == "xla":
                es, ea = _enum_xla(
                    sizes_j,
                    probs_j,
                    jnp.asarray(ob),
                    strides=tuple(int(s) for s in strides),
                    radix=tuple(int(r) for r in num_stages),
                    k_total=k_total,
                    tile=tile,
                )
            else:
                sz_p, pr_p, st_p, rx_p = _permuted(
                    [sizes, probs, strides.astype(np.int32),
                     num_stages.astype(np.int32)],
                    ob,
                )
                es, ea = K.sojourn_enum(
                    jnp.asarray(sz_p, fdt),
                    jnp.asarray(pr_p, fdt),
                    jnp.asarray(st_p),
                    jnp.asarray(rx_p),
                    k_total,
                    interpret=interpret,
                )
            e_succ_parts.append(np.asarray(es))
            e_all_parts.append(np.asarray(ea))
    else:
        if weights is None:
            raise ValueError("explicit outcomes need weights")
        outcomes = np.asarray(outcomes, dtype=np.int32)
        if impl != "xla":
            oc_t, wt_t = _tile_outcomes(outcomes, weights)
            oc_j, wt_j = jnp.asarray(oc_t), jnp.asarray(wt_t, fdt)
        else:
            oc_j = jnp.asarray(outcomes)
            wt_j = jnp.asarray(weights, fdt)
        pb = _order_batch(orders.shape[0], outcomes.shape[0], n)
        for lo in range(0, orders.shape[0], pb):
            ob = orders[lo : lo + pb]
            if impl == "xla":
                es, ea = _outcomes_xla(
                    sizes_j,
                    jnp.asarray(num_stages, jnp.int32),
                    oc_j,
                    wt_j,
                    jnp.asarray(ob),
                )
            else:
                sz_p, rx_p = _permuted(
                    [sizes, num_stages.astype(np.int32)], ob
                )
                es, ea = K.sojourn_outcomes(
                    jnp.asarray(sz_p, fdt),
                    jnp.asarray(rx_p),
                    jnp.asarray(ob),
                    oc_j,
                    wt_j,
                    interpret=interpret,
                )
            e_succ_parts.append(np.asarray(es))
            e_all_parts.append(np.asarray(ea))
    return np.concatenate(e_succ_parts), np.concatenate(e_all_parts)
