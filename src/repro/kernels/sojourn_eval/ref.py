"""Dense oracles for the fused sojourn evaluators.

Materializes the full ``(K, N)`` decoded outcome matrix (so it is only
usable at small K) and evaluates every order against it with the exact
math of the paper's Eqs. (7)-(9).  This is the parity reference for both
the Pallas kernels and the tiled XLA path in ``ops.py``.

``ref_sojourn_dynamic`` is the corresponding oracle for stage-level
index policies (SR / SERPT / conditional-RANK): a deliberately naive
per-combination Python simulation of W-server stage-boundary
preemption (a dict of in-flight finish times, ``n_servers=1`` by
default), structured as a while-loop over server decisions so that it
shares no code (and no bugs) with the vectorized lockstep paths it
checks (``evaluator._dynamic_batch`` and ``dynamic.py``) nor with the
unified DES in ``core/des/engine.py``.

``ref_mc_outcomes`` replays the streaming-Monte-Carlo counter stream
host-side (NumPy Threefry, :mod:`repro.kernels.sojourn_eval.rng`) into
a dense ``(S, N)`` outcome table: the streamed kernels decode the same
``(seed, sample, job)`` counters in-tile, so evaluating this table with
``ref_sojourn`` / ``ref_sojourn_dynamic`` is the oracle for the
``samples=`` mode, and the table itself matches the in-kernel stream
bitwise.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.sojourn_eval import rng

__all__ = [
    "mixed_radix_strides",
    "ref_decode",
    "ref_mc_outcomes",
    "ref_sojourn",
    "ref_sojourn_dynamic",
]


def mixed_radix_strides(num_stages: np.ndarray) -> np.ndarray:
    """Strides s.t. ``stage_i(k) = (k // stride_i) % M_i``; job 0 is the
    most-significant digit (matches ``np.meshgrid(..., indexing="ij")``)."""
    rev = np.cumprod(np.asarray(num_stages, dtype=np.int64)[::-1])[::-1]
    return np.concatenate([rev[1:], [1]])


def ref_decode(num_stages: np.ndarray, k_total: int) -> np.ndarray:
    """(K, N) decoded stop-stage matrix for all combinations."""
    strides = mixed_radix_strides(num_stages)
    k = np.arange(k_total, dtype=np.int64)
    return ((k[:, None] // strides[None, :]) % np.asarray(num_stages)[None, :]).astype(
        np.int32
    )


def ref_mc_outcomes(
    probs: np.ndarray,  # (N, M) padded stop probabilities
    num_stages: np.ndarray,  # (N,) stage counts
    seed: int,
    n_samples: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Dense host replay of the streamed-MC outcome stream.

    Returns ``(outcomes (S, N) int32, weights (S,) = 1/S)`` — bitwise
    identical to the outcomes the streaming kernels decode in-tile for
    the same ``(seed, n_samples)``.
    """
    outcomes = rng.host_outcomes(seed, n_samples, probs, num_stages)
    weights = np.full((n_samples,), 1.0 / n_samples)
    return outcomes, weights


def ref_sojourn(
    sizes,  # (N, M) padded cumulative sizes
    probs,  # (N, M) padded stop probabilities
    num_stages,  # (N,) stage counts
    orders,  # (P, N) permutations
    outcomes=None,  # optional (K, N) explicit outcome matrix
    weights=None,  # optional (K,) combination weights
):
    """(E[sojourn successful], E[sojourn all]) per order, dense."""
    sizes = jnp.asarray(sizes)
    num_stages = np.asarray(num_stages)
    n = sizes.shape[0]
    if outcomes is None:
        k_total = int(np.prod(num_stages, dtype=np.int64))
        outcomes = ref_decode(num_stages, k_total)
        weights = np.prod(
            np.asarray(probs)[np.arange(n)[None, :], outcomes], axis=1
        )
    outcomes = jnp.asarray(outcomes)
    weights = jnp.asarray(weights)
    d = sizes[jnp.arange(n)[None, :], outcomes]  # (K, N)
    succ = outcomes == jnp.asarray(num_stages)[None, :] - 1
    cnt = jnp.sum(succ, axis=1)
    e_succ, e_all = [], []
    for order in np.asarray(orders):
        t = jnp.cumsum(jnp.take(d, order, axis=1), axis=1)
        tot = jnp.sum(t * jnp.take(succ, order, axis=1), axis=1)
        mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), 0.0)
        e_succ.append(jnp.dot(weights, mean))
        e_all.append(jnp.dot(weights, jnp.mean(t, axis=1)))
    return jnp.stack(e_succ), jnp.stack(e_all)


def ref_sojourn_dynamic(
    probs,  # (N, M) padded stop probabilities
    stage_durs,  # (N, M) padded per-stage service increments
    num_stages,  # (N,) stage counts
    idx_table,  # (N, M) conditional index table (+inf pad)
    outcomes=None,  # optional (K, N) explicit outcome matrix
    weights=None,  # optional (K,) combination weights
    n_servers=1,  # W homogeneous servers
) -> tuple[float, float]:
    """(E[sojourn successful], E[sojourn all]) for one index policy, dense.

    Per combination: while a server is free, seat the alive unserved job
    with the minimum conditional index (ties to the lowest job
    position); then advance to the earliest finishing segment (ties to
    the lowest job position) and either record the job's completion (it
    reached its decoded outcome stage) or requeue it at its next
    conditional index.  ``n_servers=1`` degenerates to the classic
    serve-one-segment-at-a-time loop.  Success == stopping at the last
    stage.
    """
    probs = np.asarray(probs, dtype=np.float64)
    stage_durs = np.asarray(stage_durs, dtype=np.float64)
    num_stages = np.asarray(num_stages)
    idx_table = np.asarray(idx_table, dtype=np.float64)
    n = len(num_stages)
    if outcomes is None:
        k_total = int(np.prod(num_stages, dtype=np.int64))
        outcomes = ref_decode(num_stages, k_total)
        weights = np.prod(
            probs[np.arange(n)[None, :], outcomes], axis=1
        )
    e_succ = 0.0
    e_all = 0.0
    for outcome, w in zip(np.asarray(outcomes), np.asarray(weights)):
        stage = [0] * n
        done = [False] * n
        completion = [0.0] * n
        finish: dict[int, float] = {}  # job -> busy-until
        clock = 0.0
        while not all(done):
            while len(finish) < n_servers:
                best, best_j = np.inf, -1
                for j in range(n):
                    if done[j] or j in finish:
                        continue
                    if idx_table[j, stage[j]] < best:
                        best, best_j = idx_table[j, stage[j]], j
                if best_j < 0:
                    break  # queue empty: leave servers idle
                finish[best_j] = clock + stage_durs[best_j, stage[best_j]]
            j = min(finish, key=lambda q: (finish[q], q))
            clock = finish.pop(j)
            if stage[j] == outcome[j]:
                done[j] = True
                completion[j] = clock
            else:
                stage[j] += 1
        succ = [j for j in range(n) if outcome[j] == num_stages[j] - 1]
        if succ:
            e_succ += w * float(np.mean([completion[j] for j in succ]))
        e_all += w * float(np.mean(completion))
    return e_succ, e_all
