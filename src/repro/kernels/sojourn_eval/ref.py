"""Dense pure-jnp oracle for the fused sojourn evaluator.

Materializes the full ``(K, N)`` decoded outcome matrix (so it is only
usable at small K) and evaluates every order against it with the exact
math of the paper's Eqs. (7)-(9).  This is the parity reference for both
the Pallas kernels and the tiled XLA path in ``ops.py``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["mixed_radix_strides", "ref_decode", "ref_sojourn"]


def mixed_radix_strides(num_stages: np.ndarray) -> np.ndarray:
    """Strides s.t. ``stage_i(k) = (k // stride_i) % M_i``; job 0 is the
    most-significant digit (matches ``np.meshgrid(..., indexing="ij")``)."""
    rev = np.cumprod(np.asarray(num_stages, dtype=np.int64)[::-1])[::-1]
    return np.concatenate([rev[1:], [1]])


def ref_decode(num_stages: np.ndarray, k_total: int) -> np.ndarray:
    """(K, N) decoded stop-stage matrix for all combinations."""
    strides = mixed_radix_strides(num_stages)
    k = np.arange(k_total, dtype=np.int64)
    return ((k[:, None] // strides[None, :]) % np.asarray(num_stages)[None, :]).astype(
        np.int32
    )


def ref_sojourn(
    sizes,  # (N, M) padded cumulative sizes
    probs,  # (N, M) padded stop probabilities
    num_stages,  # (N,) stage counts
    orders,  # (P, N) permutations
    outcomes=None,  # optional (K, N) explicit outcome matrix
    weights=None,  # optional (K,) combination weights
):
    """(E[sojourn successful], E[sojourn all]) per order, dense."""
    sizes = jnp.asarray(sizes)
    num_stages = np.asarray(num_stages)
    n = sizes.shape[0]
    if outcomes is None:
        k_total = int(np.prod(num_stages, dtype=np.int64))
        outcomes = ref_decode(num_stages, k_total)
        weights = np.prod(
            np.asarray(probs)[np.arange(n)[None, :], outcomes], axis=1
        )
    outcomes = jnp.asarray(outcomes)
    weights = jnp.asarray(weights)
    d = sizes[jnp.arange(n)[None, :], outcomes]  # (K, N)
    succ = outcomes == jnp.asarray(num_stages)[None, :] - 1
    cnt = jnp.sum(succ, axis=1)
    e_succ, e_all = [], []
    for order in np.asarray(orders):
        t = jnp.cumsum(jnp.take(d, order, axis=1), axis=1)
        tot = jnp.sum(t * jnp.take(succ, order, axis=1), axis=1)
        mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), 0.0)
        e_succ.append(jnp.dot(weights, mean))
        e_all.append(jnp.dot(weights, jnp.mean(t, axis=1)))
    return jnp.stack(e_succ), jnp.stack(e_all)
