"""Splittable counter-based RNG shared by every streaming-MC path.

One Threefry-2x32 block (Salmon et al., Random123; the same generator
family JAX's default PRNG uses) written against a generic array
namespace ``xp`` so the *identical* integer arithmetic runs

* host-side in NumPy (the ``ref.py`` oracle replay and
  ``evaluator.sample_outcomes``-style parity tests),
* in the jitted XLA fallbacks (``jnp`` under ``lax.scan``), and
* inside the Pallas tiles (``jnp`` on ``(SUBLANES, LANES)`` registers —
  only elementwise uint32 add/xor/shift, all Mosaic-supported).

Because all three paths execute the same uint32 recurrence on the same
``(sample_index, job_index)`` counters under the same key, the outcome
streams agree *bitwise*: a Monte-Carlo sweep never materializes an
``(S, N)`` sample table on device, yet the host oracle can replay any
slice of the stream exactly, and two policies evaluated under one seed
see identical outcome sequences (common random numbers).

Counter layout: ``x0 = sample_index``, ``x1 = job_index`` (each a full
32-bit word, so streams of 2**31+ samples never collide), keyed by the
two 31-bit halves of a user seed (31 bits so the words round-trip
through int32 SMEM scalars on TPU).  The first output word, scaled by
``2**-32``, is the per-(sample, job) uniform; an inverse-CDF count over
the padded per-job CDF turns it into a stop-stage outcome exactly as
:func:`repro.core.evaluator.sample_outcomes` does.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "split_seed",
    "threefry2x32",
    "uniform_from_bits",
    "host_uniforms",
    "host_outcomes",
]

#: Threefry-2x32 rotation schedule (Random123), alternating per 4-round group.
_ROT_A = (13, 15, 26, 6)
_ROT_B = (17, 29, 16, 24)
#: Key-schedule parity constant for Threefry-32.
_PARITY = 0x1BD11BDA

MAX_SEED = 1 << 62


def split_seed(seed: int) -> tuple[int, int]:
    """Split a 62-bit seed into two 31-bit key words (int32-safe)."""
    if not 0 <= seed < MAX_SEED:
        raise ValueError(f"seed must be in [0, 2**62); got {seed}")
    return seed & 0x7FFFFFFF, (seed >> 31) & 0x7FFFFFFF


def _rotl(xp, x, r: int):
    return (x << xp.uint32(r)) | (x >> xp.uint32(32 - r))


def threefry2x32(xp, key: tuple, x0, x1):
    """One 20-round Threefry-2x32 block; uint32 in, (uint32, uint32) out.

    ``xp`` is ``numpy`` or ``jax.numpy``; ``key`` is a pair of uint32
    scalars (or 0-d arrays) and ``x0``/``x1`` uint32 arrays of any
    (broadcastable) shape.
    """
    k0, k1 = (xp.uint32(key[0]), xp.uint32(key[1]))
    ks2 = k0 ^ k1 ^ xp.uint32(_PARITY)
    x0 = x0 + k0
    x1 = x1 + k1
    subkeys = ((k1, ks2), (ks2, k0), (k0, k1), (k1, ks2), (ks2, k0))
    rots = (_ROT_A, _ROT_B, _ROT_A, _ROT_B, _ROT_A)
    for i, (rot4, (ka, kb)) in enumerate(zip(rots, subkeys)):
        for r in rot4:
            x0 = x0 + x1
            x1 = _rotl(xp, x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ka
        x1 = (x1 + kb) + xp.uint32(i + 1)
    return x0, x1


def uniform_from_bits(bits, dtype):
    """uint32 bits -> uniform in [0, 1).  Exact in float64 (bits < 2**32
    times a power of two), so comparisons against a shared CDF are
    reproducible bit-for-bit across NumPy / XLA / Pallas."""
    return bits.astype(dtype) * 2.0**-32


# ---------------------------------------------------------------------------
# Host-side replay (the ref.py oracle and parity tests ride these)
# ---------------------------------------------------------------------------


def host_uniforms(
    seed: int, sample_lo: int, n_samples: int, n_jobs: int
) -> np.ndarray:
    """(S, N) float64 uniforms for samples [sample_lo, sample_lo + S)."""
    k0, k1 = split_seed(seed)
    t = np.arange(sample_lo, sample_lo + n_samples, dtype=np.int64)
    x0 = np.broadcast_to(t[:, None], (n_samples, n_jobs)).astype(np.uint32)
    x1 = np.broadcast_to(
        np.arange(n_jobs, dtype=np.int64)[None, :], (n_samples, n_jobs)
    ).astype(np.uint32)
    bits, _ = threefry2x32(np, (k0, k1), x0, x1)
    return uniform_from_bits(bits, np.float64)


def host_outcomes(
    seed: int, n_samples: int, probs: np.ndarray, num_stages: np.ndarray
) -> np.ndarray:
    """(S, N) int32 stop-stage outcomes: the dense replay of the stream.

    Inverse-CDF count over ``cumsum(probs)`` with the same comparison
    direction (``u >= cdf``) and clamp as the in-kernel search, so the
    result is bitwise identical to what the streaming evaluators decode.
    """
    probs = np.asarray(probs, dtype=np.float64)
    num_stages = np.asarray(num_stages)
    cdf = np.cumsum(probs, axis=1)  # padded stages add 0 mass
    u = host_uniforms(seed, 0, n_samples, probs.shape[0])
    outcomes = np.sum(u[:, :, None] >= cdf[None, :, :], axis=2)
    return np.minimum(outcomes, num_stages[None, :] - 1).astype(np.int32)
