"""Fused expected-sojourn evaluation of static orders as Pallas kernels.

The exact evaluation scheme (paper §IV-A1, Eqs. 7-9) scores a static
non-preemptive order by enumerating every per-job outcome combination.
The seed implementation materialized the full ``(K, N)`` outcome matrix
in host NumPy (capping K at 2**21); these kernels never materialize it:

* ``sojourn_enum`` — each grid tile owns ``BLOCK_COMBOS`` *combination
  indices* and decodes them on the fly with the mixed-radix rule
  ``stage_i(k) = (k // stride_i) % M_i`` (job 0 is the most-significant
  digit, matching :func:`repro.core.evaluator.enumerate_outcomes`).
  Realized durations / termination probabilities are gathered from the
  padded ``(N, M)`` tables by a one-hot select over the (small) stage
  axis — TPU-friendly: no vector gather, only ``(SUBLANES, LANES)``
  selects.  The per-order completion-time prefix sum runs in the same
  position loop, and the probability-weighted successful-job sojourn
  accumulates into a VMEM scratch tile that persists across the
  (sequential, innermost) combination-tile grid dimension.

* ``sojourn_outcomes`` — the same fused gather + prefix sum + weighted
  reduction for an *explicit* outcome matrix (Monte-Carlo samples or a
  shared exact table).  The ``(K, N)`` int32 matrix is streamed through
  VMEM in ``(SUBLANES, LANES)``-shaped tiles laid out stage-major, so
  the float duration/success matrices of the seed path are never built.

* ``sojourn_mc`` — streaming Monte-Carlo: each grid tile owns
  ``BLOCK_COMBOS`` *sample indices* and generates the per-job outcome
  in-register from the counter-based Threefry stream
  (:mod:`repro.kernels.sojourn_eval.rng`): ``(seed, sample, job)`` ->
  uniform -> inverse-CDF count over the cached per-job CDF.  No
  ``(S, N)`` sample table exists on host or device, and the counter is
  keyed by *original* job id, so every order (and the dynamic op's
  policies) evaluated under one seed sees the identical outcome stream
  (common random numbers).

Both kernels take per-*order* inputs (grid dim 0) whose job axis is
pre-permuted by the caller (``ops.py``), so position ``pos`` in the
kernel loop *is* service position: the running sum ``t`` after ``pos``
steps is the completion time of the job served ``pos``-th.

Accumulation happens in the input dtype: float64 under
``jax.experimental.enable_x64`` (CPU interpret / XLA paths — this is
what the exact evaluator uses and what the <=1e-9 parity tests check),
float32 on real TPU grids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sojourn_eval import rng

__all__ = [
    "sojourn_enum",
    "sojourn_outcomes",
    "sojourn_mc",
    "BLOCK_COMBOS",
    "SUBLANES",
    "LANES",
]

SUBLANES = 8  # float32 min sublane count
LANES = 128  # TPU lane width
#: Combination indices decoded / streamed per grid tile.
BLOCK_COMBOS = SUBLANES * LANES


def _tile_combo_ids(kt: jax.Array) -> jax.Array:
    """(SUBLANES, LANES) combination indices owned by tile ``kt``."""
    row = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 1)
    return kt * BLOCK_COMBOS + row * LANES + col


def _flush(succ_ref, all_ref, acc_succ, acc_all):
    succ_ref[0, 0] = jnp.sum(acc_succ[...])
    all_ref[0, 0] = jnp.sum(acc_all[...])


# ---------------------------------------------------------------------------
# Enumeration mode: decode combination indices on the fly (Eqs. 7-9 exact)
# ---------------------------------------------------------------------------


def _enum_kernel(
    strides_ref,  # (1, N) int32 SMEM, per-order permuted mixed-radix strides
    radix_ref,  # (1, N) int32 SMEM, per-order permuted stage counts M_i
    sizes_ref,  # (1, N, M) VMEM, per-order permuted cumulative sizes
    probs_ref,  # (1, N, M) VMEM, per-order permuted stop probabilities
    succ_ref,  # (1, 1) out: E[sojourn | successful jobs] accumulator
    all_ref,  # (1, 1) out: E[sojourn | all jobs]
    acc_succ,  # (SUBLANES, LANES) VMEM scratch
    acc_all,
    *,
    n: int,
    m: int,
    k_total: int,
    nkt: int,
):
    kt = pl.program_id(1)

    @pl.when(kt == 0)
    def _init():
        acc_succ[...] = jnp.zeros_like(acc_succ)
        acc_all[...] = jnp.zeros_like(acc_all)

    dtype = acc_succ.dtype
    k = _tile_combo_ids(kt)
    # Eq. (8): combination probability = prod_i p_{i, stage_i(k)}; the tail
    # tile is masked by zeroing its weight (k >= K contributes nothing).
    w = (k < k_total).astype(dtype)
    t = jnp.zeros((SUBLANES, LANES), dtype)  # completion time at position pos
    tsum = jnp.zeros((SUBLANES, LANES), dtype)  # sum of completion times
    tot = jnp.zeros((SUBLANES, LANES), dtype)  # sum over successful jobs
    cnt = jnp.zeros((SUBLANES, LANES), jnp.int32)  # successes l(k)
    for pos in range(n):
        stride = strides_ref[0, pos]
        radix = radix_ref[0, pos]
        s = (k // stride) % radix  # on-the-fly mixed-radix decode
        d = jnp.zeros((SUBLANES, LANES), dtype)
        p = jnp.zeros((SUBLANES, LANES), dtype)
        for j in range(m):  # one-hot gather over the (small) stage axis
            hit = s == j
            d = jnp.where(hit, sizes_ref[0, pos, j], d)
            p = jnp.where(hit, probs_ref[0, pos, j], p)
        w = w * p
        t = t + d
        succ = s == radix - 1
        tot = jnp.where(succ, tot + t, tot)
        cnt = cnt + succ.astype(jnp.int32)
        tsum = tsum + t
    # Eq. (7): mean sojourn of the l(k) successful jobs (0 when l = 0);
    # Eq. (9): the probability-weighted sum, tiled into the scratch.
    mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1).astype(dtype), 0.0)
    acc_succ[...] += w * mean
    acc_all[...] += w * (tsum / n)

    @pl.when(kt == nkt - 1)
    def _finalize():
        _flush(succ_ref, all_ref, acc_succ, acc_all)


def sojourn_enum(
    sizes_p: jax.Array,  # (P, N, M) per-order permuted cumulative sizes
    probs_p: jax.Array,  # (P, N, M) per-order permuted probabilities
    strides_p: jax.Array,  # (P, N) int32 permuted mixed-radix strides
    radix_p: jax.Array,  # (P, N) int32 permuted stage counts
    k_total: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Exact (E[sojourn successful], E[sojourn all]) per order, fused."""
    p_orders, n, m = sizes_p.shape
    nkt = max(1, pl.cdiv(k_total, BLOCK_COMBOS))
    dtype = sizes_p.dtype
    kernel = functools.partial(_enum_kernel, n=n, m=m, k_total=k_total, nkt=nkt)
    out_succ, out_all = pl.pallas_call(
        kernel,
        grid=(p_orders, nkt),
        in_specs=[
            pl.BlockSpec((1, n), lambda p, kt: (p, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n), lambda p, kt: (p, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n, m), lambda p, kt: (p, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p, kt: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p_orders, 1), dtype),
            jax.ShapeDtypeStruct((p_orders, 1), dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), dtype),
            pltpu.VMEM((SUBLANES, LANES), dtype),
        ],
        interpret=interpret,
    )(strides_p, radix_p, sizes_p, probs_p)
    return out_succ[:, 0], out_all[:, 0]


# ---------------------------------------------------------------------------
# Explicit-outcome mode: stream a (K, N) outcome matrix (MC / shared tables)
# ---------------------------------------------------------------------------


def _outcomes_kernel(
    order_ref,  # (1, N) int32 SMEM: original job id served at each position
    radix_ref,  # (1, N) int32 SMEM, per-order permuted stage counts
    sizes_ref,  # (1, N, M) VMEM, per-order permuted cumulative sizes
    outcomes_ref,  # (N, 1, SUBLANES, LANES) int32 VMEM, original job indexing
    weights_ref,  # (1, SUBLANES, LANES) VMEM, zero-padded combination weights
    succ_ref,  # (1, 1) out
    all_ref,  # (1, 1) out
    acc_succ,
    acc_all,
    *,
    n: int,
    m: int,
    nkt: int,
):
    kt = pl.program_id(1)

    @pl.when(kt == 0)
    def _init():
        acc_succ[...] = jnp.zeros_like(acc_succ)
        acc_all[...] = jnp.zeros_like(acc_all)

    dtype = acc_succ.dtype
    w = weights_ref[0]  # tail tiles are weight-padded with zeros
    t = jnp.zeros((SUBLANES, LANES), dtype)
    tsum = jnp.zeros((SUBLANES, LANES), dtype)
    tot = jnp.zeros((SUBLANES, LANES), dtype)
    cnt = jnp.zeros((SUBLANES, LANES), jnp.int32)
    for pos in range(n):
        job = order_ref[0, pos]
        radix = radix_ref[0, pos]
        s = outcomes_ref[job, 0]  # (SUBLANES, LANES) realized stop stages
        d = jnp.zeros((SUBLANES, LANES), dtype)
        for j in range(m):
            d = jnp.where(s == j, sizes_ref[0, pos, j], d)
        t = t + d
        succ = s == radix - 1
        tot = jnp.where(succ, tot + t, tot)
        cnt = cnt + succ.astype(jnp.int32)
        tsum = tsum + t
    mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1).astype(dtype), 0.0)
    acc_succ[...] += w * mean
    acc_all[...] += w * (tsum / n)

    @pl.when(kt == nkt - 1)
    def _finalize():
        _flush(succ_ref, all_ref, acc_succ, acc_all)


def sojourn_outcomes(
    sizes_p: jax.Array,  # (P, N, M) per-order permuted cumulative sizes
    radix_p: jax.Array,  # (P, N) int32 permuted stage counts
    orders: jax.Array,  # (P, N) int32 original job ids by position
    outcomes_t: jax.Array,  # (N, KT, SUBLANES, LANES) int32 streamed tiles
    weights_t: jax.Array,  # (KT, SUBLANES, LANES) zero-padded weights
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused static-order evaluation over an explicit outcome matrix."""
    p_orders, n, m = sizes_p.shape
    nkt = weights_t.shape[0]
    dtype = sizes_p.dtype
    kernel = functools.partial(_outcomes_kernel, n=n, m=m, nkt=nkt)
    out_succ, out_all = pl.pallas_call(
        kernel,
        grid=(p_orders, nkt),
        in_specs=[
            pl.BlockSpec((1, n), lambda p, kt: (p, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n), lambda p, kt: (p, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n, m), lambda p, kt: (p, 0, 0)),
            pl.BlockSpec((n, 1, SUBLANES, LANES), lambda p, kt: (0, kt, 0, 0)),
            pl.BlockSpec((1, SUBLANES, LANES), lambda p, kt: (kt, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p_orders, 1), dtype),
            jax.ShapeDtypeStruct((p_orders, 1), dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), dtype),
            pltpu.VMEM((SUBLANES, LANES), dtype),
        ],
        interpret=interpret,
    )(orders, radix_p, sizes_p, outcomes_t, weights_t)
    return out_succ[:, 0], out_all[:, 0]


# ---------------------------------------------------------------------------
# Streaming Monte-Carlo mode: counter-based RNG outcome generation in-tile
# ---------------------------------------------------------------------------


def _mc_kernel(
    seed_ref,  # (1, 2) int32 SMEM: the two 31-bit Threefry key words
    order_ref,  # (1, N) int32 SMEM: original job id served at each position
    radix_ref,  # (1, N) int32 SMEM, per-order permuted stage counts
    sizes_ref,  # (1, N, M) VMEM, per-order permuted cumulative sizes
    cdf_ref,  # (1, N, M) VMEM, per-order permuted stop-probability CDF
    succ_ref,  # (1, 1) out
    all_ref,  # (1, 1) out
    acc_succ,
    acc_all,
    *,
    n: int,
    m: int,
    n_samples: int,
    nkt: int,
):
    kt = pl.program_id(1)

    @pl.when(kt == 0)
    def _init():
        acc_succ[...] = jnp.zeros_like(acc_succ)
        acc_all[...] = jnp.zeros_like(acc_all)

    dtype = acc_succ.dtype
    k = _tile_combo_ids(kt)  # lanes own global sample indices
    key = (seed_ref[0, 0].astype(jnp.uint32), seed_ref[0, 1].astype(jnp.uint32))
    x0 = k.astype(jnp.uint32)
    # Uniform MC weights; tail lanes (k >= S) are masked to zero.
    w = (k < n_samples).astype(dtype) * (1.0 / n_samples)
    t = jnp.zeros((SUBLANES, LANES), dtype)
    tsum = jnp.zeros((SUBLANES, LANES), dtype)
    tot = jnp.zeros((SUBLANES, LANES), dtype)
    cnt = jnp.zeros((SUBLANES, LANES), jnp.int32)
    for pos in range(n):
        job = order_ref[0, pos]  # RNG counter keyed by ORIGINAL job id
        radix = radix_ref[0, pos]
        x1 = (jnp.zeros((SUBLANES, LANES), jnp.int32) + job).astype(jnp.uint32)
        bits, _ = rng.threefry2x32(jnp, key, x0, x1)
        u = rng.uniform_from_bits(bits, dtype)
        # Inverse-CDF count, identical comparisons to the host replay.
        scnt = jnp.zeros((SUBLANES, LANES), jnp.int32)
        for j in range(m):
            scnt = scnt + (u >= cdf_ref[0, pos, j]).astype(jnp.int32)
        s = jnp.minimum(scnt, radix - 1)
        d = jnp.zeros((SUBLANES, LANES), dtype)
        for j in range(m):
            d = jnp.where(s == j, sizes_ref[0, pos, j], d)
        t = t + d
        succ = s == radix - 1
        tot = jnp.where(succ, tot + t, tot)
        cnt = cnt + succ.astype(jnp.int32)
        tsum = tsum + t
    mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1).astype(dtype), 0.0)
    acc_succ[...] += w * mean
    acc_all[...] += w * (tsum / n)

    @pl.when(kt == nkt - 1)
    def _finalize():
        _flush(succ_ref, all_ref, acc_succ, acc_all)


def sojourn_mc(
    sizes_p: jax.Array,  # (P, N, M) per-order permuted cumulative sizes
    cdf_p: jax.Array,  # (P, N, M) per-order permuted stop-probability CDF
    radix_p: jax.Array,  # (P, N) int32 permuted stage counts
    orders: jax.Array,  # (P, N) int32 original job ids by position
    seed: int,
    n_samples: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Streamed-MC (E[sojourn successful], E[sojourn all]) per order."""
    p_orders, n, m = sizes_p.shape
    nkt = max(1, pl.cdiv(n_samples, BLOCK_COMBOS))
    dtype = sizes_p.dtype
    seed_arr = jnp.asarray([rng.split_seed(seed)], jnp.int32)  # (1, 2)
    kernel = functools.partial(
        _mc_kernel, n=n, m=m, n_samples=n_samples, nkt=nkt
    )
    out_succ, out_all = pl.pallas_call(
        kernel,
        grid=(p_orders, nkt),
        in_specs=[
            pl.BlockSpec((1, 2), lambda p, kt: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n), lambda p, kt: (p, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n), lambda p, kt: (p, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n, m), lambda p, kt: (p, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p, kt: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p_orders, 1), dtype),
            jax.ShapeDtypeStruct((p_orders, 1), dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), dtype),
            pltpu.VMEM((SUBLANES, LANES), dtype),
        ],
        interpret=interpret,
    )(seed_arr, orders, radix_p, sizes_p, cdf_p)
    return out_succ[:, 0], out_all[:, 0]
