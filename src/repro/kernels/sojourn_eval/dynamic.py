"""Fused dynamic-policy (SR / SERPT / conditional-RANK) sojourn evaluator.

Kernel design note — in-tile lockstep simulation of index policies
==================================================================

The paper's stage-level policies (§III-A, §IV-V) re-rank jobs at every
checkpoint: each of the W servers always serves the alive job with the
minimum *conditional* index, where SOAP-style (Scully & Harchol-Balter)
the whole policy is described by its rank function — here a precomputed
``(N, M)`` table ``idx[i, s]`` = job i's priority after surviving ``s``
checkpoints (:func:`repro.core.policies.index_table`).  Exact evaluation
(Eqs. 7-9) therefore needs, per outcome combination, a *simulation*
rather than a prefix sum; the seed path (``evaluator._dynamic_batch``)
runs that simulation over a fully materialized ``(K, N)`` outcome table
and is capped at ``MAX_MATERIALIZED_COMBOS = 2**21``.

These kernels lift the dynamic path to the same streaming scheme as the
static ``sojourn_enum`` op — no ``(K, N)`` table anywhere, exact to
``MAX_EXACT_COMBOS = 2**26``:

* **Tile layout** — the grid is ``(P policies, ceil(K / BLOCK_COMBOS))``
  with the combination axis innermost (sequential).  Each tile owns
  ``BLOCK_COMBOS = 8 x 128`` combination indices as one
  ``(SUBLANES, LANES)`` VPU tile and decodes the stop stage of every job
  on the fly with the shared mixed-radix rule
  ``stage_i(k) = (k // stride_i) % M_i`` (identical decoder and digit
  order as the static kernel and ``enumerate_outcomes``).  The Eq.-8
  weight ``w = prod_i p_{i, stage_i}`` is accumulated during the decode
  via one-hot selects over the small stage axis; tail combinations
  ``k >= K`` carry zero weight.

* **In-tile multi-server lockstep** — every lane then simulates its own
  combination in lockstep over ``sum_i M_i`` completion events on
  ``n_servers = W`` homogeneous servers.  The per-lane state is one
  current-stage register and one ``busy_until`` register per job
  (``+inf`` while not running) plus a busy count, clock and sojourn
  accumulators.  After seating the W smallest-index jobs at t=0 (W
  unrolled dispatch passes), each step (a ``fori_loop``) unrolls two
  passes over the (static) job axis:

  1. *complete*: pop the running job with the earliest ``busy_until``
     via a running minimum with a strict ``<`` compare — ties break
     toward the lowest job position, exactly matching the unified DES's
     event heap (``(time, seq)`` ordering).  The lane clock advances to
     the finish time; if the finished segment reaches the decoded stop
     stage the job's completion time is folded into the successful /
     all-job sojourn accumulators (success == stopping at stage
     ``M_j - 1``), else the job rejoins the queue at its next
     conditional index.  If nothing is running the sentinel "job" ``n``
     matches nothing and the step is a no-op.
  2. *dispatch*: seat the queued job with the minimum conditional index
     ``idx[j, stage_j]`` (one-hot gathers, strict ``<`` running
     minimum, ties by position — ``jnp.argmin`` semantics) on the freed
     server, ``busy_until = clock + stage_durs[j, stage_j]``.  One pass
     suffices: a completion frees exactly one server and requeues at
     most one job, so the queue and the free pool can never both be
     nonempty after it.  With ``W = 1`` the math reduces bitwise to the
     single-server kernel of PR 7 (``busy = clock + dur`` then
     ``clock = busy``).

* **Reduction** — after the step loop the lane holds Eq. (7)'s mean
  sojourn of successful jobs for its combination; the tile accumulates
  ``w * mean`` into a VMEM scratch accumulator that persists across the
  sequential combination tiles and is flushed on the last one — the
  same tiled reduction as the static kernel.

The XLA fallback (`_dynamic_enum_xla`) is the identical algorithm as a
``lax.scan`` over combination tiles with the job axis vectorized
(``(T, N)`` state, ``argmin`` selection); it is the default on CPU and
the path the exact evaluator rides.  Both paths accumulate in the input
dtype: float64 under ``jax.experimental.enable_x64`` (the <=1e-9 parity
bar), float32 on real TPU grids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sojourn_eval import kernel as K
from repro.kernels.sojourn_eval import rng
from repro.kernels.sojourn_eval.ref import mixed_radix_strides
from repro.obs import profiling

__all__ = ["sojourn_eval_dynamic", "dynamic_sojourn_enum", "dynamic_sojourn_mc"]

#: Combination indices per XLA scan tile (bounded-memory streaming).
XLA_TILE = 1 << 15


# ---------------------------------------------------------------------------
# Pallas kernel: per-tile lockstep simulation
# ---------------------------------------------------------------------------


def _lockstep_sim(
    sdec, succ, idx_s, dur_s, *, n, m, total_stages, dtype, n_servers=1
):
    """Shared in-tile lockstep multi-server simulation.

    Every lane simulates its own outcome combination (``sdec[j]`` = the
    decoded stop stage of job ``j`` per lane, however it was produced —
    mixed-radix enumeration or the Threefry MC stream) in lockstep over
    ``total_stages`` completion events on ``n_servers`` homogeneous
    servers.  Per-lane state is one current-stage register and one
    ``busy_until`` register per job (``+inf`` while not running).  Each
    step pops the earliest-finishing running job (ties by job position),
    advances the lane clock to its finish time, then seats the
    minimum-index queued job on the freed server; since a completion
    event adds at most one job back to the queue and servers free one at
    a time, a single dispatch pass per step is exhaustive.  The t=0
    seating of the ``min(W, N)`` smallest-index jobs happens before the
    loop.  Returns per-lane ``(tot, tsum, cnt)``: summed successful
    completion times, summed all-job completion times, and the success
    count.  ``n_servers=1`` reproduces the single-server math bitwise
    (``busy = clock + dur`` then ``clock = busy``).
    """
    shape = (K.SUBLANES, K.LANES)
    inf = jnp.full(shape, jnp.inf, dtype)
    zf = jnp.zeros(shape, dtype)
    zi = jnp.zeros(shape, jnp.int32)
    w_srv = min(n_servers, n)

    def _gather(table_j, st, fill):
        v = fill
        for s_ in range(m):
            v = jnp.where(st == s_, table_j[s_], v)
        return v

    def _dispatch_one(stages, busy, nbusy, clock):
        # seat the queued job with the minimum conditional index on a
        # free server; strict < keeps the first minimum (ties by job
        # position).  Sentinel ``n`` when the queue is empty.
        best = inf
        bestj = jnp.full(shape, n, jnp.int32)
        for j in range(n):
            st = stages[j]
            queued = (busy[j] == jnp.inf) & (st <= sdec[j])
            idx_j = jnp.where(queued, _gather(idx_s[j], st, inf), inf)
            better = idx_j < best
            best = jnp.where(better, idx_j, best)
            bestj = jnp.where(better, j, bestj)
        can = (nbusy < w_srv) & (bestj < n)
        new_busy = []
        for j in range(n):
            sel = can & (bestj == j)
            d_j = _gather(dur_s[j], stages[j], zf)
            new_busy.append(jnp.where(sel, clock + d_j, busy[j]))
        return tuple(new_busy), nbusy + can.astype(jnp.int32)

    def step(_, carry):
        stages, busy, nbusy, clock, tot, tsum, cnt = carry
        # completion: pop the running job with the earliest finish time;
        # strict < keeps the first minimum (ties by job position).
        tmin = inf
        cjob = jnp.full(shape, n, jnp.int32)  # sentinel: nothing running
        for j in range(n):
            better = busy[j] < tmin
            tmin = jnp.where(better, busy[j], tmin)
            cjob = jnp.where(better, j, cjob)
        has = cjob < n
        clock = jnp.where(has, tmin, clock)
        fin_any = jnp.zeros(shape, jnp.bool_)
        fin_succ = jnp.zeros(shape, jnp.bool_)
        new_stages, new_busy = [], []
        for j in range(n):
            sel = cjob == j
            st = stages[j]
            fin_j = sel & (st == sdec[j])
            fin_any = fin_any | fin_j
            fin_succ = fin_succ | (fin_j & succ[j])
            new_stages.append(st + sel.astype(jnp.int32))
            new_busy.append(jnp.where(sel, inf, busy[j]))
        nbusy = nbusy - has.astype(jnp.int32)
        tot = jnp.where(fin_succ, tot + clock, tot)
        cnt = cnt + fin_succ.astype(jnp.int32)
        tsum = jnp.where(fin_any, tsum + clock, tsum)
        # refill the freed server: at most one job (re)joined the queue,
        # so one dispatch pass per completion is exhaustive.
        busy2, nbusy = _dispatch_one(
            tuple(new_stages), tuple(new_busy), nbusy, clock
        )
        return tuple(new_stages), busy2, nbusy, clock, tot, tsum, cnt

    stages0 = tuple(zi for _ in range(n))
    busy0 = tuple(inf for _ in range(n))
    nbusy0 = zi
    for _ in range(w_srv):  # t=0: seat the W smallest-index jobs
        busy0, nbusy0 = _dispatch_one(stages0, busy0, nbusy0, zf)
    init = (stages0, busy0, nbusy0, zf, zf, zf, zi)
    _, _, _, _, tot, tsum, cnt = jax.lax.fori_loop(0, total_stages, step, init)
    return tot, tsum, cnt


def _dynamic_kernel(
    strides_ref,  # (1, N) int32 SMEM mixed-radix strides (original job order)
    radix_ref,  # (1, N) int32 SMEM stage counts M_i
    probs_ref,  # (1, N, M) VMEM stop probabilities (0 pad)
    durs_ref,  # (1, N, M) VMEM per-stage service increments (0 pad)
    idx_ref,  # (1, N, M) VMEM this policy's index table (+inf pad)
    succ_ref,  # (1, 1) out: E[sojourn | successful jobs]
    all_ref,  # (1, 1) out: E[sojourn | all jobs]
    acc_succ,  # (SUBLANES, LANES) VMEM scratch
    acc_all,
    *,
    n: int,
    m: int,
    total_stages: int,
    k_total: int,
    nkt: int,
    n_servers: int,
):
    kt = pl.program_id(1)

    @pl.when(kt == 0)
    def _init():
        acc_succ[...] = jnp.zeros_like(acc_succ)
        acc_all[...] = jnp.zeros_like(acc_all)

    dtype = acc_succ.dtype
    shape = (K.SUBLANES, K.LANES)
    k = K._tile_combo_ids(kt)
    # Scalar tables, hoisted out of the step loop.
    idx_s = [[idx_ref[0, j, s] for s in range(m)] for j in range(n)]
    dur_s = [[durs_ref[0, j, s] for s in range(m)] for j in range(n)]

    # --- decode: stop stage, success flag and Eq.-8 weight per lane -------
    w = (k < k_total).astype(dtype)  # tail tiles carry zero weight
    sdec, succ = [], []
    for j in range(n):
        radix = radix_ref[0, j]
        s = (k // strides_ref[0, j]) % radix
        p = jnp.zeros(shape, dtype)
        for s_ in range(m):  # one-hot gather over the (small) stage axis
            p = jnp.where(s == s_, probs_ref[0, j, s_], p)
        w = w * p
        sdec.append(s)
        succ.append(s == radix - 1)

    # --- lockstep multi-server simulation (stage-boundary preemption) ---
    tot, tsum, cnt = _lockstep_sim(
        sdec, succ, idx_s, dur_s, n=n, m=m, total_stages=total_stages,
        dtype=dtype, n_servers=n_servers,
    )

    # Eq. (7) mean over the successful jobs; Eq. (9) weighted reduction.
    mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1).astype(dtype), 0.0)
    acc_succ[...] += w * mean
    acc_all[...] += w * (tsum / n)

    @pl.when(kt == nkt - 1)
    def _finalize():
        K._flush(succ_ref, all_ref, acc_succ, acc_all)


def _dynamic_mc_kernel(
    seed_ref,  # (1, 2) int32 SMEM: the two 31-bit Threefry key words
    radix_ref,  # (1, N) int32 SMEM stage counts M_i
    cdf_ref,  # (1, N, M) VMEM stop-probability CDF (cumsum of probs)
    durs_ref,  # (1, N, M) VMEM per-stage service increments (0 pad)
    idx_ref,  # (1, N, M) VMEM this policy's index table (+inf pad)
    succ_ref,  # (1, 1) out
    all_ref,  # (1, 1) out
    acc_succ,
    acc_all,
    *,
    n: int,
    m: int,
    total_stages: int,
    n_samples: int,
    nkt: int,
    n_servers: int,
):
    """Streamed-MC variant: lanes own sample indices and decode each
    job's stop stage from the Threefry counter stream instead of the
    mixed-radix rule; the lockstep simulation is shared."""
    kt = pl.program_id(1)

    @pl.when(kt == 0)
    def _init():
        acc_succ[...] = jnp.zeros_like(acc_succ)
        acc_all[...] = jnp.zeros_like(acc_all)

    dtype = acc_succ.dtype
    shape = (K.SUBLANES, K.LANES)
    k = K._tile_combo_ids(kt)  # lanes own global sample indices
    key = (seed_ref[0, 0].astype(jnp.uint32), seed_ref[0, 1].astype(jnp.uint32))
    x0 = k.astype(jnp.uint32)
    idx_s = [[idx_ref[0, j, s] for s in range(m)] for j in range(n)]
    dur_s = [[durs_ref[0, j, s] for s in range(m)] for j in range(n)]

    # Uniform MC weights; tail lanes (k >= S) are masked to zero.
    w = (k < n_samples).astype(dtype) * (1.0 / n_samples)
    sdec, succ = [], []
    for j in range(n):
        radix = radix_ref[0, j]
        x1 = (jnp.zeros(shape, jnp.int32) + j).astype(jnp.uint32)
        bits, _ = rng.threefry2x32(jnp, key, x0, x1)
        u = rng.uniform_from_bits(bits, dtype)
        scnt = jnp.zeros(shape, jnp.int32)
        for s_ in range(m):  # inverse-CDF count, same compares as host
            scnt = scnt + (u >= cdf_ref[0, j, s_]).astype(jnp.int32)
        s = jnp.minimum(scnt, radix - 1)
        sdec.append(s)
        succ.append(s == radix - 1)

    tot, tsum, cnt = _lockstep_sim(
        sdec, succ, idx_s, dur_s, n=n, m=m, total_stages=total_stages,
        dtype=dtype, n_servers=n_servers,
    )

    mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1).astype(dtype), 0.0)
    acc_succ[...] += w * mean
    acc_all[...] += w * (tsum / n)

    @pl.when(kt == nkt - 1)
    def _finalize():
        K._flush(succ_ref, all_ref, acc_succ, acc_all)


def dynamic_sojourn_enum(
    probs: jax.Array,  # (N, M) padded stop probabilities
    stage_durs: jax.Array,  # (N, M) padded per-stage increments
    idx_tables: jax.Array,  # (P, N, M) per-policy index tables (+inf pad)
    strides: jax.Array,  # (N,) int32 mixed-radix strides
    radix: jax.Array,  # (N,) int32 stage counts
    k_total: int,
    total_stages: int,
    *,
    n_servers: int = 1,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Exact (E[sojourn successful], E[sojourn all]) per policy, fused."""
    p_pols, n, m = idx_tables.shape
    nkt = max(1, pl.cdiv(k_total, K.BLOCK_COMBOS))
    dtype = idx_tables.dtype
    kernel = functools.partial(
        _dynamic_kernel,
        n=n,
        m=m,
        total_stages=total_stages,
        k_total=k_total,
        nkt=nkt,
        n_servers=n_servers,
    )
    out_succ, out_all = pl.pallas_call(
        kernel,
        grid=(p_pols, nkt),
        in_specs=[
            pl.BlockSpec((1, n), lambda p, kt: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n), lambda p, kt: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n, m), lambda p, kt: (0, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p, kt: (0, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p, kt: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p_pols, 1), dtype),
            jax.ShapeDtypeStruct((p_pols, 1), dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((K.SUBLANES, K.LANES), dtype),
            pltpu.VMEM((K.SUBLANES, K.LANES), dtype),
        ],
        interpret=interpret,
    )(
        strides.reshape(1, n),
        radix.reshape(1, n),
        probs.reshape(1, n, m),
        stage_durs.reshape(1, n, m),
        idx_tables,
    )
    return out_succ[:, 0], out_all[:, 0]


def dynamic_sojourn_mc(
    cdf: jax.Array,  # (N, M) stop-probability CDF
    stage_durs: jax.Array,  # (N, M) padded per-stage increments
    idx_tables: jax.Array,  # (P, N, M) per-policy index tables (+inf pad)
    radix: jax.Array,  # (N,) int32 stage counts
    seed: int,
    n_samples: int,
    total_stages: int,
    *,
    n_servers: int = 1,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Streamed-MC (E[sojourn successful], E[sojourn all]) per policy."""
    p_pols, n, m = idx_tables.shape
    nkt = max(1, pl.cdiv(n_samples, K.BLOCK_COMBOS))
    dtype = idx_tables.dtype
    seed_arr = jnp.asarray([rng.split_seed(seed)], jnp.int32)  # (1, 2)
    kernel = functools.partial(
        _dynamic_mc_kernel,
        n=n,
        m=m,
        total_stages=total_stages,
        n_samples=n_samples,
        nkt=nkt,
        n_servers=n_servers,
    )
    out_succ, out_all = pl.pallas_call(
        kernel,
        grid=(p_pols, nkt),
        in_specs=[
            pl.BlockSpec((1, 2), lambda p, kt: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n), lambda p, kt: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n, m), lambda p, kt: (0, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p, kt: (0, 0, 0)),
            pl.BlockSpec((1, n, m), lambda p, kt: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, kt: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p_pols, 1), dtype),
            jax.ShapeDtypeStruct((p_pols, 1), dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((K.SUBLANES, K.LANES), dtype),
            pltpu.VMEM((K.SUBLANES, K.LANES), dtype),
        ],
        interpret=interpret,
    )(
        seed_arr,
        radix.reshape(1, n),
        cdf.reshape(1, n, m),
        stage_durs.reshape(1, n, m),
        idx_tables,
    )
    return out_succ[:, 0], out_all[:, 0]


# ---------------------------------------------------------------------------
# XLA streaming fallback: same algorithm, job axis vectorized
# ---------------------------------------------------------------------------


def _sim_tile_xla(
    s, succ, idx_table, stage_durs, job_ids, *, m, total_stages, n_servers=1
):
    """Shared per-tile lockstep simulation, job axis vectorized.

    ``s`` is the (T, N) decoded stop-stage matrix for this tile (from
    the mixed-radix rule or the Threefry MC stream); returns per-lane
    ``(tot, tsum, cnt)`` as in :func:`_lockstep_sim`.  Same multi-server
    state machine (per-job ``busy_until`` row, completion pop + one
    dispatch pass per step) with ``argmin`` standing in for the unrolled
    running-minimum passes — both keep the first minimum on ties.
    """
    tile, n = s.shape
    dtype = stage_durs.dtype
    inf_row = jnp.full((tile, n), jnp.inf, dtype)
    w_srv = min(n_servers, n)

    def _tables(stage):
        idx = inf_row
        dur = jnp.zeros((tile, n), dtype)
        for s_ in range(m):  # one-hot gather over the stage axis
            hit = stage == s_
            idx = jnp.where(hit, idx_table[None, :, s_], idx)
            dur = jnp.where(hit, stage_durs[None, :, s_], dur)
        return idx, dur

    def _dispatch_one(stage, busy, nbusy, clock):
        idx, dur = _tables(stage)
        queued = (busy == jnp.inf) & (stage <= s)
        idxq = jnp.where(queued, idx, jnp.inf)
        j = jnp.argmin(idxq, axis=1)  # first minimum: ties by position
        can = (nbusy < w_srv) & jnp.isfinite(jnp.min(idxq, axis=1))
        sel = (j[:, None] == job_ids) & can[:, None] & queued
        busy = jnp.where(sel, clock[:, None] + dur, busy)
        return busy, nbusy + can.astype(jnp.int32)

    def body(_, st):
        stage, busy, nbusy, clock, tot, tsum, cnt = st
        tmin = jnp.min(busy, axis=1)
        cj = jnp.argmin(busy, axis=1)  # earliest finish; ties by position
        has = jnp.isfinite(tmin)  # all-idle lanes: no-op
        clock = jnp.where(has, tmin, clock)
        sel = (cj[:, None] == job_ids) & has[:, None]
        fin = sel & (stage == s)
        fin_any = jnp.any(fin, axis=1)
        fin_succ = jnp.any(fin & succ, axis=1)
        tot = tot + jnp.where(fin_succ, clock, 0.0)
        cnt = cnt + fin_succ.astype(jnp.int32)
        tsum = tsum + jnp.where(fin_any, clock, 0.0)
        stage = stage + sel.astype(jnp.int32)
        busy = jnp.where(sel, jnp.inf, busy)
        nbusy = nbusy - has.astype(jnp.int32)
        busy, nbusy = _dispatch_one(stage, busy, nbusy, clock)
        return stage, busy, nbusy, clock, tot, tsum, cnt

    zf = jnp.zeros((tile,), dtype)
    zi = jnp.zeros((tile,), jnp.int32)
    stage0 = jnp.zeros((tile, n), jnp.int32)
    busy0, nbusy0 = inf_row, zi
    for _ in range(w_srv):  # t=0: seat the W smallest-index jobs
        busy0, nbusy0 = _dispatch_one(stage0, busy0, nbusy0, zf)
    init = (stage0, busy0, nbusy0, zf, zf, zf, zi)
    _, _, _, _, tot, tsum, cnt = jax.lax.fori_loop(0, total_stages, body, init)
    return tot, tsum, cnt


@functools.partial(
    jax.jit,
    static_argnames=(
        "strides", "radix", "k_total", "tile", "total_stages", "n_servers"
    ),
)
def _dynamic_enum_xla(
    probs, stage_durs, idx_table, *, strides, radix, k_total, tile,
    total_stages, n_servers=1,
):
    """Exact fused dynamic evaluation for one policy; ``strides``/``radix``
    are static tuples so the decode lowers to constant div/mod chains."""
    n = probs.shape[0]
    m = probs.shape[1]
    dtype = probs.dtype
    strides_a = jnp.asarray(strides, jnp.int32)[None, :]
    radix_a = jnp.asarray(radix, jnp.int32)[None, :]
    job_ids = jnp.arange(n, dtype=jnp.int32)[None, :]
    n_tiles = max(1, -(-k_total // tile))

    def tile_fn(carry, t):
        e_succ, e_all = carry
        k = t * tile + jnp.arange(tile, dtype=jnp.int32)
        valid = k < k_total
        s = (k[:, None] // strides_a) % radix_a  # (T, N) on-the-fly decode
        w = jnp.prod(probs[job_ids, s], axis=1) * valid  # Eq. (8)
        succ = s == radix_a - 1
        tot, tsum, cnt = _sim_tile_xla(
            s, succ, idx_table, stage_durs, job_ids, m=m,
            total_stages=total_stages, n_servers=n_servers,
        )
        mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1).astype(dtype), 0.0)
        return (e_succ + jnp.dot(w, mean), e_all + jnp.dot(w, tsum / n)), None

    zero = jnp.zeros((), dtype)
    (e_succ, e_all), _ = jax.lax.scan(
        tile_fn, (zero, zero), jnp.arange(n_tiles, dtype=jnp.int32)
    )
    return e_succ, e_all


@functools.partial(
    jax.jit,
    static_argnames=("radix", "n_samples", "tile", "total_stages", "n_servers"),
)
def _dynamic_mc_xla(
    cdf, stage_durs, idx_table, key2, *, radix, n_samples, tile, total_stages,
    n_servers=1,
):
    """Streamed-MC dynamic evaluation for one policy: per-tile Threefry
    outcome generation (identical counters and compares to the static op
    and the host replay), then the shared lockstep simulation."""
    n = cdf.shape[0]
    m = cdf.shape[1]
    dtype = cdf.dtype
    radix_a = jnp.asarray(radix, jnp.int32)[None, :]
    job_ids = jnp.arange(n, dtype=jnp.int32)[None, :]
    n_tiles = max(1, -(-n_samples // tile))
    x1 = jnp.broadcast_to(job_ids, (tile, n)).astype(jnp.uint32)

    def tile_fn(carry, t):
        e_succ, e_all = carry
        k = t * tile + jnp.arange(tile, dtype=jnp.int32)
        x0 = jnp.broadcast_to(k[:, None], (tile, n)).astype(jnp.uint32)
        bits, _ = rng.threefry2x32(jnp, (key2[0], key2[1]), x0, x1)
        u = rng.uniform_from_bits(bits, dtype)
        s = jnp.minimum(
            jnp.sum(u[:, :, None] >= cdf[None, :, :], axis=2).astype(jnp.int32),
            radix_a - 1,
        )
        w = (k < n_samples).astype(dtype) * (1.0 / n_samples)
        succ = s == radix_a - 1
        tot, tsum, cnt = _sim_tile_xla(
            s, succ, idx_table, stage_durs, job_ids, m=m,
            total_stages=total_stages, n_servers=n_servers,
        )
        mean = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1).astype(dtype), 0.0)
        return (e_succ + jnp.dot(w, mean), e_all + jnp.dot(w, tsum / n)), None

    zero = jnp.zeros((), dtype)
    (e_succ, e_all), _ = jax.lax.scan(
        tile_fn, (zero, zero), jnp.arange(n_tiles, dtype=jnp.int32)
    )
    return e_succ, e_all


# ---------------------------------------------------------------------------
# Public op
# ---------------------------------------------------------------------------


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("xla", "pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}; options: auto/xla/pallas/interpret")
    return impl


def sojourn_eval_dynamic(
    probs: np.ndarray,  # (N, M) padded stop probabilities
    stage_durs: np.ndarray,  # (N, M) padded per-stage increments
    num_stages: np.ndarray,  # (N,) stage counts
    idx_tables: np.ndarray,  # (P, N, M) or (N, M) policy index tables
    *,
    samples: tuple[int, int] | None = None,  # (seed, n_samples) streamed MC
    n_servers: int = 1,
    impl: str = "auto",
) -> tuple[np.ndarray, np.ndarray]:
    """(E[sojourn successful], E[sojourn all]) per policy; see module doc.

    With ``samples=None``, evaluates all ``K = prod(M_i)`` outcome
    combinations exactly without materializing them, simulating the
    stage-level index policy encoded by each ``(N, M)`` table in
    ``idx_tables``.  With ``samples=(seed, n_samples)``, estimates the
    same quantities by streaming Monte Carlo: outcomes are generated
    in-tile from the counter-based Threefry stream (no ``(S, N)`` table
    anywhere), bitwise identical to the static op's stream and the
    ``ref.ref_mc_outcomes`` host replay for the same seed.
    ``n_servers=W`` evaluates the paper's online multi-server setting
    (W homogeneous servers, stage-boundary preemption, same-instant
    contention by index) — the exact analogue of the unified DES with
    all arrivals at t=0.  Returns ``(P,)`` arrays (pass a single
    ``(N, M)`` table for ``P = 1``).

    When :mod:`repro.obs.profiling` is enabled, each call is timed into
    a ``prof.sojourn_eval.dynamic.<mode>.<impl>.seconds`` span.
    """
    impl = _resolve(impl)
    mode = "mc" if samples is not None else "enum"
    with profiling.span(f"sojourn_eval.dynamic.{mode}.{impl}"):
        return _sojourn_eval_dynamic(
            probs, stage_durs, num_stages, idx_tables,
            samples=samples, n_servers=n_servers, impl=impl,
        )


def _sojourn_eval_dynamic(
    probs, stage_durs, num_stages, idx_tables, *,
    samples=None, n_servers=1, impl="xla",
) -> tuple[np.ndarray, np.ndarray]:
    if n_servers < 1:
        raise ValueError(f"n_servers must be >= 1; got {n_servers}")
    probs = np.asarray(probs)
    stage_durs = np.asarray(stage_durs)
    num_stages = np.asarray(num_stages, dtype=np.int64)
    idx_tables = np.asarray(idx_tables)
    if idx_tables.ndim == 2:
        idx_tables = idx_tables[None]
    n, m = probs.shape
    if idx_tables.shape[1:] != (n, m):
        raise ValueError(
            f"idx_tables must be (P, {n}, {m}); got {idx_tables.shape}"
        )
    total_stages = int(num_stages.sum())
    fdt = jnp.asarray(probs).dtype  # f64 under x64, else f32
    if samples is not None:
        seed, n_samples = int(samples[0]), int(samples[1])
        if n_samples <= 0:
            raise ValueError(f"n_samples must be positive; got {n_samples}")
        cdf = np.cumsum(probs, axis=1)  # padded stages add 0 mass
        if impl == "xla":
            tile = min(
                XLA_TILE, max(K.BLOCK_COMBOS, 1 << (n_samples - 1).bit_length())
            )
            key2 = jnp.asarray(rng.split_seed(seed), jnp.uint32)
            parts = [
                _dynamic_mc_xla(
                    jnp.asarray(cdf, fdt),
                    jnp.asarray(stage_durs, fdt),
                    jnp.asarray(table, fdt),
                    key2,
                    radix=tuple(int(r) for r in num_stages),
                    n_samples=n_samples,
                    tile=tile,
                    total_stages=total_stages,
                    n_servers=n_servers,
                )
                for table in idx_tables
            ]
            e_succ = np.array([float(p[0]) for p in parts])
            e_all = np.array([float(p[1]) for p in parts])
            return e_succ, e_all
        es, ea = dynamic_sojourn_mc(
            jnp.asarray(cdf, fdt),
            jnp.asarray(stage_durs, fdt),
            jnp.asarray(idx_tables, fdt),
            jnp.asarray(num_stages, jnp.int32),
            seed,
            n_samples,
            total_stages,
            n_servers=n_servers,
            interpret=impl == "interpret",
        )
        return np.asarray(es), np.asarray(ea)
    strides = mixed_radix_strides(num_stages)
    k_total = int(np.prod(num_stages, dtype=np.int64))
    if impl == "xla":
        tile = min(XLA_TILE, max(K.BLOCK_COMBOS, 1 << (k_total - 1).bit_length()))
        parts = [
            _dynamic_enum_xla(
                jnp.asarray(probs, fdt),
                jnp.asarray(stage_durs, fdt),
                jnp.asarray(table, fdt),
                strides=tuple(int(s) for s in strides),
                radix=tuple(int(r) for r in num_stages),
                k_total=k_total,
                tile=tile,
                total_stages=total_stages,
                n_servers=n_servers,
            )
            for table in idx_tables
        ]
        e_succ = np.array([float(p[0]) for p in parts])
        e_all = np.array([float(p[1]) for p in parts])
        return e_succ, e_all
    es, ea = dynamic_sojourn_enum(
        jnp.asarray(probs, fdt),
        jnp.asarray(stage_durs, fdt),
        jnp.asarray(idx_tables, fdt),
        jnp.asarray(strides, jnp.int32),
        jnp.asarray(num_stages, jnp.int32),
        k_total,
        total_stages,
        n_servers=n_servers,
        interpret=impl == "interpret",
    )
    return np.asarray(es), np.asarray(ea)
