"""FlashAttention for TPU as Pallas kernels (fwd + bwd).

TPU adaptation (vs. the CUDA flash-attention algorithm): no warp-level
primitives — instead the streaming accumulation runs across the *grid*
(the innermost grid dimension is sequential on TPU), with running
(m, l, acc) statistics held in VMEM scratch that persists across grid
steps.  Block shapes are MXU-aligned (multiples of 128 on the lane dim);
all matmuls use ``preferred_element_type=float32`` so bf16 inputs hit the
MXU with f32 accumulation.

Kernel layout is (B, H, S, D); ``ops.py`` transposes from the model's
(B, S, H, D).  GQA is handled in the index maps (query head h reads KV
head ``h // group``), so KV is never materialized per-q-head in HBM.

Causal/sliding-window structure is exploited at the *block* level: the
k-grid still iterates all blocks (Pallas grids are dense) but fully
masked blocks are skipped via ``pl.when`` — on TPU this skips the compute
while the (cheap) index bookkeeping proceeds.

Backward follows the two-kernel FlashAttention-2 scheme:
  * ``_dkv_kernel``: grid (B, Hkv, nk, G, nq) — for a fixed KV block,
    stream all query heads in the GQA group and all q blocks, accumulating
    dK/dV in scratch.  (G, nq) are the two innermost dims so the dK/dV
    output block index is constant across them — a legal TPU revisit.
  * ``_dq_kernel``:  grid (B, Hq, nq, nk) — accumulate dQ over KV blocks.
Both consume the forward LSE and the precomputed ``delta = rowsum(dO*O)``
(computed in ops.py; it is a cheap elementwise reduction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_fwd", "flash_dkv", "flash_dq"]

NEG_INF = -1e30  # large-but-finite: avoids NaN from (-inf) - (-inf)
LANES = 128  # TPU lane width: scratch stat tiles are (bq, LANES)


def _block_visible(q_start, q_end, k_start, k_end, causal: bool, window):
    """Whether any (i, j) pair in the block can be visible."""
    vis = jnp.bool_(True)
    if causal:
        vis &= k_start <= q_end  # some key <= some query
    if window is not None:
        vis &= k_end > q_start - window
    return vis


def _pair_mask(q_ids, k_ids, causal: bool, window):
    """(bq, bk) boolean visibility for explicit in-block masking."""
    q = q_ids[:, None]
    k = k_ids[None, :]
    m = jnp.ones((q_ids.shape[0], k_ids.shape[0]), dtype=bool)
    if causal:
        m &= k <= q
    if window is not None:
        m &= k > q - window
    return m


def _needs_mask(q_start, q_end, k_start, k_end, causal: bool, window):
    """Whether the block is only *partially* visible (mask must be applied)."""
    need = jnp.bool_(False)
    if causal:
        need |= k_end > q_start  # some key could exceed some query
    if window is not None:
        need |= k_start <= q_end - window
    return need


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, window, bq, bk, nk,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_start = qi * bq
    q_end = q_start + bq - 1
    k_start = ki * bk
    k_end = k_start + bk - 1

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(_block_visible(q_start, q_end, k_start, k_end, causal, window))
    def _compute():
        q = q_ref[0, 0]  # (bq, d)
        k = k_ref[0, 0]  # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if causal or window is not None:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)[:, 0]
            k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)[0, :]
            s = jnp.where(_pair_mask(q_ids, k_ids, causal, window), s, NEG_INF)

        m_prev = m_ref[:, 0]  # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)  # (bq,)
        p = jnp.exp(s - m_cur[:, None])  # (bq, bk)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_cur
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(
            o_ref.dtype
        )
        # LSE; rows with no visible keys keep NEG_INF-ish values -> exp()=0.
        lse_ref[0, 0] = m_ref[:, 0] + jnp.log(jnp.maximum(l, 1e-30))


def flash_fwd(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq ({sq},{skv}) not divisible by blocks ({bq},{bk})")
    nq, nk = sq // bq, skv // bk

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk, nk=nk
    )
    out_shape = [
        jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
    ]
    o, lse = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, qi, ki, g=g: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, qi, ki, g=g: (b_, h // g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, qi, ki: (b_, h, qi)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# Backward: dK/dV kernel
# ---------------------------------------------------------------------------


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_acc, dv_acc, *, scale, causal, window, bq, bk, ng, nq,
):
    gi = pl.program_id(3)
    qi = pl.program_id(4)
    ki = pl.program_id(2)

    q_start = qi * bq
    q_end = q_start + bq - 1
    k_start = ki * bk
    k_end = k_start + bk - 1

    @pl.when((gi == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(_block_visible(q_start, q_end, k_start, k_end, causal, window))
    def _compute():
        q = q_ref[0, 0]  # (bq, d)
        k = k_ref[0, 0]  # (bk, d)
        v = v_ref[0, 0]
        do = do_ref[0, 0].astype(jnp.float32)  # (bq, d)
        lse = lse_ref[0, 0]  # (bq,)
        delta = delta_ref[0, 0]  # (bq,)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if causal or window is not None:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)[:, 0]
            k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)[0, :]
            s = jnp.where(_pair_mask(q_ids, k_ids, causal, window), s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # (bq, bk), true probabilities
        # dV += P^T dO
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        # dS = P * (dO V^T - delta)
        dp = jax.lax.dot_general(
            do, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dp - delta[:, None])  # (bq, bk)
        # dK += dS^T Q * scale
        dk_acc[...] += scale * jax.lax.dot_general(
            ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when((gi == ng - 1) & (qi == nq - 1))
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_dkv(
    q, k, v, do, lse, delta, *, scale, causal, window,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    nq, nk = sq // bq, skv // bk

    kernel = functools.partial(
        _dkv_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, ng=g, nq=nq,
    )
    # index maps: query head = kvh * g + gi
    qmap = lambda b_, kvh, ki, gi, qi, g=g: (b_, kvh * g + gi, qi, 0)
    kmap = lambda b_, kvh, ki, gi, qi: (b_, kvh, ki, 0)
    lmap = lambda b_, kvh, ki, gi, qi, g=g: (b_, kvh * g + gi, qi)
    dk, dv = pl.pallas_call(
        kernel,
        grid=(b, hkv, nk, g, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), qmap),
            pl.BlockSpec((1, 1, bk, d), kmap),
            pl.BlockSpec((1, 1, bk, d), kmap),
            pl.BlockSpec((1, 1, bq, d), qmap),
            pl.BlockSpec((1, 1, bq), lmap),
            pl.BlockSpec((1, 1, bq), lmap),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), kmap),
            pl.BlockSpec((1, 1, bk, d), kmap),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, skv, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, skv, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dk, dv


# ---------------------------------------------------------------------------
# Backward: dQ kernel
# ---------------------------------------------------------------------------


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_acc,
    *, scale, causal, window, bq, bk, nk,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_start = qi * bq
    q_end = q_start + bq - 1
    k_start = ki * bk
    k_end = k_start + bk - 1

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    @pl.when(_block_visible(q_start, q_end, k_start, k_end, causal, window))
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if causal or window is not None:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)[:, 0]
            k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)[0, :]
            s = jnp.where(_pair_mask(q_ids, k_ids, causal, window), s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(
            do, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dp - delta[:, None])
        dq_acc[...] += scale * jax.lax.dot_general(
            ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def flash_dq(
    q, k, v, do, lse, delta, *, scale, causal, window,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    nq, nk = sq // bq, skv // bk

    kernel = functools.partial(
        _dq_kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk, nk=nk
    )
    qmap = lambda b_, h, qi, ki: (b_, h, qi, 0)
    kmap = lambda b_, h, qi, ki, g=g: (b_, h // g, ki, 0)
    lmap = lambda b_, h, qi, ki: (b_, h, qi)
    dq = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), qmap),
            pl.BlockSpec((1, 1, bk, d), kmap),
            pl.BlockSpec((1, 1, bk, d), kmap),
            pl.BlockSpec((1, 1, bq, d), qmap),
            pl.BlockSpec((1, 1, bq), lmap),
            pl.BlockSpec((1, 1, bq), lmap),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), qmap),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq
