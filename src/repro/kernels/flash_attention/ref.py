"""Pure-jnp reference attention (the oracle and the XLA dispatch path).

Layout convention (matches the models): q (B, Sq, Hq, D); k, v
(B, Skv, Hkv, D) with Hq a multiple of Hkv (GQA).  Softmax statistics in
float32 regardless of input dtype; output cast back to q.dtype.

Masking supports ``causal`` and a sliding window of size ``window``
(key j visible to query i iff i - window < j <= i, the Mistral/Mixtral
convention), and an optional ``kv_len`` for decode against a padded
cache (keys at positions >= kv_len are masked out).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ref_attention"]


def _mask_bias(
    sq: int,
    skv: int,
    causal: bool,
    window: int | None,
    kv_len=None,
    q_offset=None,
):
    """(Sq, Skv) additive bias in f32: 0 where visible, -inf where masked."""
    q_idx = jnp.arange(sq)[:, None]
    if q_offset is not None:
        q_idx = q_idx + q_offset  # decode: absolute query position
    k_idx = jnp.arange(skv)[None, :]
    visible = jnp.ones((sq, skv), dtype=bool)
    if causal:
        visible &= k_idx <= q_idx
    if window is not None:
        visible &= k_idx > q_idx - window
    if kv_len is not None:
        visible &= k_idx < kv_len
    return jnp.where(visible, 0.0, -jnp.inf).astype(jnp.float32)


def ref_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    kv_len=None,
    q_offset=None,
) -> jnp.ndarray:
    """O(Sq*Skv) softmax attention with GQA head broadcasting."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    g = hq // hkv
    if scale is None:
        scale = d**-0.5

    # Inputs stay in their storage dtype (bf16 on the real path): the dots
    # accumulate in f32 via preferred_element_type, so no f32 copies of the
    # (potentially huge) K/V tensors are ever materialized — dot(bf16,bf16
    # ->f32) is bit-identical to dot(f32(bf16), f32(bf16)) and matches the
    # Pallas kernel's MXU usage.  P is cast to V's dtype before the PV dot,
    # exactly as the kernel does.
    qg = q.reshape(b, sq, hkv, g, d)
    # scores: (B, Hkv, G, Sq, Skv), f32
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) * jnp.float32(scale)
    s = s + _mask_bias(sq, skv, causal, window, kv_len, q_offset)[None, None, None]
    # Guard all-masked rows (possible when kv_len == 0): softmax of -inf row.
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    o = jnp.einsum(
        "bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(b, sq, hq, d).astype(q.dtype)
