"""Public fused-attention op with implementation dispatch + custom VJP.

``impl``:
  * "xla"       — :func:`repro.kernels.flash_attention.ref.ref_attention`
                  (differentiable via jax AD).  Default on CPU: used for
                  smoke training runs and for dry-run lowering (same math
                  and FLOPs as the kernel; collectives unaffected).
  * "pallas"    — the TPU Pallas kernel (compiled via Mosaic).
  * "interpret" — the Pallas kernel interpreted on CPU (correctness tests).
  * "auto"      — "pallas" on TPU backends, else "xla".

The Pallas paths carry a custom VJP (FlashAttention-2 two-kernel backward)
so the same op is usable in train_step.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import ref_attention

__all__ = ["flash_attention"]

Impl = Literal["auto", "xla", "pallas", "interpret"]


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# (B, S, H, D) <-> kernel layout (B, H, S, D)
def _to_k(x):
    return jnp.swapaxes(x, 1, 2)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _flash_pallas(q, k, v, scale, causal, window, block_q_k, interpret):
    o, _ = K.flash_fwd(
        q, k, v, scale=scale, causal=causal, window=window,
        block_q=block_q_k[0], block_k=block_q_k[1], interpret=interpret,
    )
    return o


def _flash_fwd_rule(q, k, v, scale, causal, window, block_q_k, interpret):
    o, lse = K.flash_fwd(
        q, k, v, scale=scale, causal=causal, window=window,
        block_q=block_q_k[0], block_k=block_q_k[1], interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(scale, causal, window, block_q_k, interpret, res, do):
    q, k, v, o, lse = res
    # delta = rowsum(dO * O): cheap elementwise; done at the jnp level.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    common = dict(
        scale=scale, causal=causal, window=window,
        block_q=block_q_k[0], block_k=block_q_k[1], interpret=interpret,
    )
    dk, dv = K.flash_dkv(q, k, v, do, lse, delta, **common)
    dq = K.flash_dq(q, k, v, do, lse, delta, **common)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_pallas.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    impl: Impl = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Fused multi-head attention; see module docstring for ``impl``."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    impl = _resolve(impl)
    if impl == "xla":
        return ref_attention(q, k, v, causal=causal, window=window, scale=scale)
    interpret = impl == "interpret"
    o = _flash_pallas(
        _to_k(q), _to_k(k), _to_k(v), scale, causal, window,
        (block_q, block_k), interpret,
    )
    return _to_k(o)
