"""Public SSD op with implementation dispatch + custom VJP.

Forward dispatch mirrors flash_attention.ops.  The backward of the Pallas
path recomputes through :func:`ref.ssd_chunked` (jax AD over the chunked
scan): the SSD backward is itself a chunked scan of the same cost class,
and recompute keeps the kernel surface small while remaining exact
(validated against AD of the oracle in tests).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as K
from repro.kernels.ssd_scan.ref import ssd_chunked

__all__ = ["ssd_scan"]

Impl = Literal["auto", "xla", "pallas", "interpret"]


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _ssd_pallas(x, dt, A, Bm, Cm, D, chunk, interpret):
    return _ssd_pallas_fwd(x, dt, A, Bm, Cm, D, chunk, interpret)[0]


def _ssd_pallas_fwd(x, dt, A, Bm, Cm, D, chunk, interpret):
    b, s, h, p = x.shape
    xk = jnp.swapaxes(x, 1, 2)  # (B, H, S, P)
    dtk = jnp.moveaxis(dt, 1, 2)  # (B, H, S)
    dak = dtk * A[None, :, None].astype(dtk.dtype)
    Bk = jnp.swapaxes(Bm, 1, 2)  # (B, G, S, N)
    Ck = jnp.swapaxes(Cm, 1, 2)
    y, st = K.ssd_fwd(xk, dtk, dak, Bk, Ck, chunk=chunk, interpret=interpret)
    y = jnp.swapaxes(y, 1, 2) + (D[None, None, :, None] * x).astype(y.dtype)
    out = (y.astype(x.dtype), jnp.swapaxes(st, 1, 1))  # st already (B,H,N,P)
    return out, (x, dt, A, Bm, Cm, D)


def _ssd_pallas_bwd(chunk, interpret, res, cts):
    x, dt, A, Bm, Cm, D = res
    dy, dstate = cts

    def f(x, dt, A, Bm, Cm, D):
        return ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)

    _, vjp = jax.vjp(f, x, dt, A, Bm, Cm, D)
    return vjp((dy, dstate))


_ssd_pallas.defvjp(
    lambda x, dt, A, Bm, Cm, D, chunk, interpret: (
        _ssd_pallas_fwd(x, dt, A, Bm, Cm, D, chunk, interpret)
    ),
    _ssd_pallas_bwd,
)


def ssd_scan(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (softplus-ed, > 0)
    A: jax.Array,   # (H,)       (negative)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    D: jax.Array,   # (H,)
    *,
    chunk: int = 128,
    impl: Impl = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan; returns (y (B,S,H,P), final_state (B,H,N,P))."""
    impl = _resolve(impl)
    if impl == "xla":
        return ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
    return _ssd_pallas(x, dt, A, Bm, Cm, D, chunk, impl == "interpret")
