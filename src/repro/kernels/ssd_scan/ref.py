"""Reference implementations of the Mamba-2 SSD (state-space duality) scan.

Semantics (discretized selective state space, arXiv:2405.21060):

    h[t] = exp(dt[t] * A) * h[t-1] + dt[t] * x[t] ⊗ B[t]
    y[t] = C[t] · h[t] + D * x[t]

with per-head scalar decay ``A < 0``, per-step ``dt > 0`` (softplus applied
upstream), states h of shape (N, P) per head.

Two oracles:

* :func:`ssd_quadratic` — O(S²) fully-materialized "attention form";
  ground truth for tests (small S only).
* :func:`ssd_chunked`   — O(S·C) chunked scan in pure jnp (lax.scan over
  chunks).  This is the differentiable XLA dispatch path used for CPU
  training and dry-run lowering, and the algorithmic blueprint the Pallas
  kernel implements with VMEM tiles.

Shapes: x (B, S, H, P); dt (B, S, H); A (H,); Bm/Cm (B, S, G, N) with
H a multiple of G; D (H,).  Returns y (B, S, H, P) and final state
(B, H, N, P).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_quadratic", "ssd_chunked"]


def _expand_groups(m: jnp.ndarray, h: int) -> jnp.ndarray:
    """(B, S, G, N) -> (B, S, H, N) by repeating each group H/G times."""
    g = m.shape[2]
    return jnp.repeat(m, h // g, axis=2)


def ssd_quadratic(x, dt, A, Bm, Cm, D, init_state=None):
    """O(S²) materialized form: y = (C·Bᵀ ∘ L) (dt∘x) + D x."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = _expand_groups(Bm.astype(jnp.float32), h)
    Cf = _expand_groups(Cm.astype(jnp.float32), h)
    dA = dtf * A.astype(jnp.float32)  # (B, S, H), <= 0
    cum = jnp.cumsum(dA, axis=1)  # (B, S, H)
    # L[t, s'] = exp(cum[t] - cum[s']) for t >= s' else 0
    diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B, T, S', H)
    tri = jnp.tril(jnp.ones((s, s), dtype=bool))
    L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bthn,bshn->btsh", Cf, Bf) * L  # (B, T, S', H)
    xb = xf * dtf[..., None]  # (B, S, H, P)
    y = jnp.einsum("btsh,bshp->bthp", scores, xb)
    if init_state is not None:
        # contribution of the incoming state: C[t] exp(cum[t]) · h0
        y = y + jnp.einsum(
            "bthn,bhnp->bthp", Cf * jnp.exp(cum)[..., None], init_state.astype(jnp.float32)
        )
    y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    # final state: h[S-1] = sum_s exp(cum[-1]-cum[s]) dt[s] B[s] ⊗ x[s] (+ decayed h0)
    w = jnp.exp(cum[:, -1:, :] - cum) * dtf  # (B, S, H)
    state = jnp.einsum("bshn,bshp->bhnp", Bf * w[..., None], xf)
    if init_state is not None:
        state = state + jnp.exp(cum[:, -1])[:, :, None, None] * init_state.astype(
            jnp.float32
        )
    return y.astype(x.dtype), state


def ssd_chunked(x, dt, A, Bm, Cm, D, init_state=None, chunk: int = 128):
    """O(S·C) chunked scan (lax.scan over chunks) — differentiable XLA path."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    nc = s // chunk
    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = _expand_groups(Bm.astype(jnp.float32), h).reshape(b, nc, chunk, h, n)
    Cf = _expand_groups(Cm.astype(jnp.float32), h).reshape(b, nc, chunk, h, n)
    Af = A.astype(jnp.float32)

    dA = dtf * Af  # (B, NC, C, H)
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative
    tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))

    def body(state, inp):
        xc, dtc, Bc, Cc, cumc = inp  # leading dim B; chunk axis next
        # intra-chunk ("diagonal block")
        diff = cumc[:, :, None, :] - cumc[:, None, :, :]  # (B, T, S', H)
        L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bthn,bshn->btsh", Cc, Bc) * L
        y = jnp.einsum("btsh,bshp->bthp", scores, xc * dtc[..., None])
        # inter-chunk: incoming state
        y = y + jnp.einsum("bthn,bhnp->bthp", Cc * jnp.exp(cumc)[..., None], state)
        # state update
        w = jnp.exp(cumc[:, -1:, :] - cumc) * dtc  # (B, C, H)
        state = jnp.exp(cumc[:, -1])[:, :, None, None] * state + jnp.einsum(
            "bshn,bshp->bhnp", Bc * w[..., None], xc
        )
        return state, y

    state0 = (
        jnp.zeros((b, h, n, p), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    # scan over the chunk axis: move it to the front
    inps = (
        xf.swapaxes(0, 1),
        dtf.swapaxes(0, 1),
        Bf.swapaxes(0, 1),
        Cf.swapaxes(0, 1),
        cum.swapaxes(0, 1),
    )
    state, ys = jax.lax.scan(body, state0, inps)
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), state


def ssd_decode_step(x, dt, A, Bm, Cm, D, state):
    """Single-token recurrence for serving.

    x (B, H, P); dt (B, H); Bm/Cm (B, G, N); state (B, H, N, P).
    Returns (y (B, H, P), new_state).
    """
    b, h, p = x.shape
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = _expand_groups(Bm.astype(jnp.float32)[:, None], h)[:, 0]  # (B, H, N)
    Cf = _expand_groups(Cm.astype(jnp.float32)[:, None], h)[:, 0]
    decay = jnp.exp(dtf * A.astype(jnp.float32))  # (B, H)
    state = decay[..., None, None] * state + jnp.einsum(
        "bhn,bhp->bhnp", Bf * dtf[..., None], xf
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cf, state) + D.astype(jnp.float32)[:, None] * xf
    return y.astype(x.dtype), state
