"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the CUDA
implementation leans on warp-level parallel scans; on TPU we use the
*matmul form* of SSD so the MXU does the heavy lifting — per chunk of
length C the intra-chunk contribution is two (C×N)·(N×C)/(C×C)·(C×P)
matmuls, and the inter-chunk recurrence is a sequential pass over chunks
carried in VMEM scratch (the innermost grid dim is sequential on TPU, so
the (N, P) running state simply persists across chunk steps).

Grid: (B, H, n_chunks).  Per-step VMEM working set (C=256, N=128, P=64,
f32): x (C,P) 64 KiB, B/C (C,N) 128 KiB each, L (C,C) 256 KiB, state
(N,P) 32 KiB — comfortably under the ~16 MiB v5e VMEM budget, with C and
P both MXU-aligned (multiples of 128/64).

Inputs are pre-arranged by ops.py to kernel layout:
  x  (B, H, S, P)   dt (B, H, S)   dA (B, H, S)  [= dt * A[h], <= 0]
  Bm (B, G, S, N)   Cm (B, G, S, N)
Outputs: y (B, H, S, P) and the final state (B, H, N, P) (for decode
priming / sequence-parallel chaining).  The D·x skip and group expansion
are handled outside (elementwise; XLA fuses them).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_fwd"]


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, st_ref, state,
                *, chunk, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, 0].astype(jnp.float32)  # (C, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (C,)
    da = da_ref[0, 0].astype(jnp.float32)  # (C,)
    Bc = b_ref[0, 0].astype(jnp.float32)  # (C, N)
    Cc = c_ref[0, 0].astype(jnp.float32)  # (C, N)

    cum = jnp.cumsum(da)  # (C,)
    # intra-chunk lower-triangular decay matrix  L[t,s] = exp(cum_t - cum_s)
    diff = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    L = jnp.where(tri, jnp.exp(diff), 0.0)  # (C, C)
    scores = (
        jax.lax.dot_general(
            Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * L
        * dt[None, :]
    )  # (C, C); column s carries the dt_s discretization weight
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, P)
    # inter-chunk: contribution of the carried state
    y += jax.lax.dot_general(
        Cc * jnp.exp(cum)[:, None], state[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: h' = exp(cum_last) h + B^T (x * dt * exp(cum_last - cum))
    w = jnp.exp(cum[-1] - cum) * dt  # (C,)
    state[...] = jnp.exp(cum[-1]) * state[...] + jax.lax.dot_general(
        Bc, x * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ci == nc - 1)
    def _finalize():
        st_ref[0, 0] = state[...]


def ssd_fwd(
    x: jax.Array,   # (B, H, S, P)
    dt: jax.Array,  # (B, H, S)
    da: jax.Array,  # (B, H, S) = dt * A[h]
    Bm: jax.Array,  # (B, G, S, N)
    Cm: jax.Array,  # (B, G, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, h, s, p = x.shape
    g = Bm.shape[1]
    n = Bm.shape[-1]
    c = min(chunk, s)
    if s % c:
        raise ValueError(f"seq {s} not divisible by chunk {c}")
    nc = s // c

    kernel = functools.partial(_ssd_kernel, chunk=c, nc=nc)
    gmap = lambda b_, h_, ci, g=g, h=h: (b_, h_ // (h // g), ci, 0)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, c, p), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, c), lambda b_, h_, ci: (b_, h_, ci)),
            pl.BlockSpec((1, 1, c), lambda b_, h_, ci: (b_, h_, ci)),
            pl.BlockSpec((1, 1, c, n), gmap),
            pl.BlockSpec((1, 1, c, n), gmap),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, p), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, da, Bm, Cm)
    return y, st
