"""Pallas TPU kernels for the data-plane hot spots.

The paper's own contribution is a scheduling policy (no kernel); these
kernels serve the *jobs* that the policy schedules — the DNN training/
serving programs whose compute hot spots dominate step time.

Each kernel package has three modules:

* ``kernel.py`` — the ``pl.pallas_call`` implementation with explicit
  BlockSpec VMEM tiling (TPU target; validated with ``interpret=True``).
* ``ops.py``    — the jit-ready public wrapper with ``impl`` dispatch
  ("xla" reference path for CPU runs & dry-run lowering, "pallas" for
  TPU, "interpret" for CPU correctness tests) and custom VJPs.
* ``ref.py``    — the pure-jnp oracle used by tests and as the XLA path.

Kernels: ``flash_attention`` (causal / sliding-window / GQA fused
attention), ``ssd_scan`` (Mamba-2 state-space duality chunked scan),
``moe_gemm`` (per-expert grouped GEMM with fused SwiGLU), and
``sojourn_eval`` — the one *control-plane* kernel: the paper's exact
Eq. (7)-(9) evaluation of E[sojourn of successful jobs], fused so the
outcome-combination matrix is decoded on the fly instead of
materialized (see that package's docstring for the tile design note).
"""
