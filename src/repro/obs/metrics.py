"""Counters / gauges / histograms with a JSON-able snapshot surface.

One :class:`MetricsRegistry` replaces the ad-hoc result dicts the
frontends used to hand-roll: the DES (:func:`repro.core.simulator.simulate`)
and the cluster manager (:meth:`repro.cluster.manager.ClusterManager.run`)
populate a registry passed by the caller, the profiling hooks
(:mod:`repro.obs.profiling`) and the workload-cache latency probes feed
the process-wide default registry, and ``python -m repro.obs.report``
dumps everything as one JSON artifact (metrics catalog in
``docs/observability.md``).

Design constraints: metric updates are hot-path cheap (an attribute
add / list append), snapshots are pure reads, and everything in a
snapshot is JSON-serializable.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "record_run_metrics",
    "format_snapshot",
]

#: Percentiles reported by histogram snapshots.
PERCENTILES = (50, 90, 95, 99)


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Value distribution; percentiles computed at snapshot time.

    Values are kept in a flat Python list (``observe``) or appended as
    numpy chunks (``observe_many``), so recording a million sojourns is
    one array append, not a million calls.
    """

    __slots__ = ("_values", "_chunks")

    def __init__(self):
        self._values: list[float] = []
        self._chunks: list[np.ndarray] = []

    def observe(self, v: float) -> None:
        self._values.append(float(v))

    def observe_many(self, values) -> None:
        arr = np.asarray(values, dtype=np.float64).ravel()
        if arr.size:
            self._chunks.append(arr)

    def _all(self) -> np.ndarray:
        parts = list(self._chunks)
        if self._values:
            parts.append(np.asarray(self._values))
        if not parts:
            return np.empty(0)
        return np.concatenate(parts)

    @property
    def count(self) -> int:
        return len(self._values) + sum(c.size for c in self._chunks)

    def snapshot(self) -> dict:
        vals = self._all()
        if vals.size == 0:
            return {"count": 0}
        out = {
            "count": int(vals.size),
            "mean": float(vals.mean()),
            "min": float(vals.min()),
            "max": float(vals.max()),
            "sum": float(vals.sum()),
        }
        pts = np.percentile(vals, PERCENTILES)
        out.update({f"p{p}": float(v) for p, v in zip(PERCENTILES, pts)})
        return out


class MetricsRegistry:
    """Name-keyed counters/gauges/histograms with get-or-create access.

    Names are dotted strings (``sojourn.successful``, ``cache.mem_hit``,
    ``prof.sojourn_eval.static.enum.xla.seconds``); a name is bound to
    the first type that claims it and re-registering as another type
    raises.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(m).__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    @contextmanager
    def timer(self, name: str):
        """Time a block into ``<name>.seconds`` (histogram)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.histogram(f"{name}.seconds").observe(time.perf_counter() - t0)

    def clear(self) -> None:
        self._metrics.clear()

    def snapshot(self) -> dict:
        """JSON-able ``{"counters": ..., "gauges": ..., "histograms": ...}``."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.snapshot()
        return out

    def to_json(self, path: str | None = None, **extra) -> str:
        """Serialize the snapshot (plus ``extra`` top-level keys)."""
        doc = {**self.snapshot(), **extra}
        text = json.dumps(doc, indent=1, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


def record_run_metrics(reg: MetricsRegistry, engine, arrivals, success) -> None:
    """Fill the standard scheduler-run metrics from a finished engine.

    Shared by both frontends so ``simulate(..., metrics=reg)`` and
    ``ClusterManager.run(metrics=reg)`` populate one catalog (see
    ``docs/observability.md``): success/cancel counts, sojourn
    percentiles split by outcome, makespan, server busy fraction
    (busy time over the time integral of the server target, so elastic
    resizes weigh correctly), and wasted work (failure-aborted stage
    time plus all service spent on jobs that end canceled).

    Counters/histograms accumulate across runs sharing a registry
    (policy sweeps); gauges are per-run, last write wins.
    """
    arrivals = np.asarray(arrivals, dtype=np.float64)
    success = np.asarray(success, dtype=bool)
    sojourn = engine.completion - arrivals
    done = ~np.isnan(sojourn)
    reg.counter("jobs.total").inc(len(arrivals))
    reg.counter("jobs.successful").inc(int((success & done).sum()))
    reg.counter("jobs.canceled").inc(int((~success & done).sum()))
    reg.histogram("sojourn.successful").observe_many(sojourn[success & done])
    reg.histogram("sojourn.canceled").observe_many(sojourn[~success & done])
    reg.gauge("run.makespan").set(engine.makespan)
    denom = engine.target_integral
    reg.gauge("servers.busy_fraction").set(
        engine.busy_time / denom if denom > 0 else 0.0
    )
    reg.gauge("work.busy_time").set(engine.busy_time)
    reg.gauge("work.aborted_time").set(engine.aborted_time)
    reg.gauge("work.wasted").set(
        engine.aborted_time + float(engine.service_time[~success].sum())
    )


_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (profiling spans, cache probes)."""
    return _DEFAULT


def format_snapshot(snapshot: dict, title: str = "metrics") -> str:
    """Render a snapshot as an aligned text block for CLI output."""
    lines = [f"== {title} =="]
    for name, v in snapshot.get("counters", {}).items():
        lines.append(f"  {name:44s} {v}")
    for name, v in snapshot.get("gauges", {}).items():
        lines.append(f"  {name:44s} {v:.6g}")
    for name, h in snapshot.get("histograms", {}).items():
        if h.get("count", 0) == 0:
            lines.append(f"  {name:44s} (empty)")
            continue
        lines.append(
            f"  {name:44s} n={h['count']} mean={h['mean']:.6g} "
            f"p50={h['p50']:.6g} p99={h['p99']:.6g} max={h['max']:.6g}"
        )
    return "\n".join(lines)
