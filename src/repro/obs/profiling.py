"""Opt-in wall-clock profiling spans for kernels and cache tiers.

Disabled by default: every probe is guarded by one module-level bool,
so the instrumented hot paths (the fused ``sojourn_eval`` ops, the
workload-cache tiers in :mod:`repro.core.policies`) pay a single
attribute check when profiling is off.  Enable with
:func:`enable` or the ``REPRO_PROFILE=1`` environment variable.

Spans record into the process-wide default
:class:`~repro.obs.metrics.MetricsRegistry` as
``prof.<name>.seconds`` histograms plus ``prof.<name>.calls``
counters, so ``python -m repro.obs.report`` (and anything else that
snapshots the registry) surfaces kernel latency next to scheduler
metrics and cache hit/miss/eviction latency in one place.

For JAX results use :func:`block` inside a span to charge async
dispatch to the span that launched it (``jax.block_until_ready``); the
``sojourn_eval`` ops convert to numpy inside their spans, which blocks
implicitly.
"""

from __future__ import annotations

import os
import time
from contextlib import contextmanager

from repro.obs import metrics

__all__ = ["enabled", "enable", "span", "block", "tick", "tock"]

_ENABLED = os.environ.get("REPRO_PROFILE", "").strip().lower() not in (
    "", "0", "false", "off",
)


def enabled() -> bool:
    return _ENABLED


def enable(on: bool = True) -> None:
    """Turn profiling spans on/off process-wide (overrides the env var)."""
    global _ENABLED
    _ENABLED = bool(on)


@contextmanager
def span(name: str, registry: metrics.MetricsRegistry | None = None):
    """Time a block into ``prof.<name>.seconds`` when profiling is on."""
    if not _ENABLED:
        yield
        return
    reg = registry or metrics.get_registry()
    t0 = time.perf_counter()
    try:
        yield
    finally:
        reg.histogram(f"prof.{name}.seconds").observe(time.perf_counter() - t0)
        reg.counter(f"prof.{name}.calls").inc()


def block(x):
    """``jax.block_until_ready`` under profiling; identity otherwise.

    Wrap a span's result so device-async work is charged to the span
    that launched it instead of the first later host sync.
    """
    if _ENABLED:
        import jax

        jax.block_until_ready(x)
    return x


def tick() -> float:
    """Start time for a hand-rolled probe; 0.0 when profiling is off.

    ``tick``/``tock`` avoid context-manager overhead on paths probed
    per cache access.
    """
    return time.perf_counter() if _ENABLED else 0.0


def tock(name: str, t0: float) -> None:
    """Close a :func:`tick` probe into ``prof.<name>.seconds``."""
    if _ENABLED and t0:
        reg = metrics.get_registry()
        reg.histogram(f"prof.{name}.seconds").observe(time.perf_counter() - t0)
        reg.counter(f"prof.{name}.calls").inc()
