"""Observability: trace recording, metrics, profiling (docs/observability.md).

Three pieces, wired through every scheduling layer:

* :class:`TraceRecorder` (:mod:`repro.obs.recorder`) — a batching DES
  observer exporting Chrome-trace/Perfetto JSON, per-server Gantt
  tables and queue-depth / utilization series from both frontends
  (``simulate(..., recorder=...)``, ``ClusterManager.run(recorder=...)``).
* :class:`MetricsRegistry` (:mod:`repro.obs.metrics`) — counters /
  gauges / histograms with a JSON snapshot, replacing ad-hoc result
  dicts; both frontends populate it via ``metrics=``.
* :mod:`repro.obs.profiling` — opt-in wall-clock spans around the fused
  ``sojourn_eval`` ops and the workload-cache tiers, surfaced in the
  same registry snapshot.

``python -m repro.obs.report`` replays a synthetic Philly-trace
workload and writes the trace + metrics artifacts.
"""

from repro.obs.metrics import (  # noqa: F401
    MetricsRegistry,
    format_snapshot,
    get_registry,
    record_run_metrics,
)
from repro.obs.recorder import TraceRecorder, validate_chrome_trace  # noqa: F401
