"""Batching trace recorder + Chrome-trace / Gantt / time-series export.

:class:`TraceRecorder` implements the engine's batched observer
protocol (:class:`repro.core.des.events.EngineObserver`): the engine
hands it flat record tuples in batches, and the recorder's hot path is
a single ``list.extend`` per batch — tracing a million-event replay
costs one Python call per ``batch_size`` events on top of the engine's
tuple appends.

Exports (all derived lazily, after the run):

* :meth:`to_chrome_trace` — Chrome trace-event JSON, loadable in
  Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  Stage
  executions become complete ("ph": "X") slices on per-server tracks,
  arrivals/failures/restarts/resizes become instants, and queue depth /
  busy servers / target become counter tracks.
* :meth:`gantt` — a per-server Gantt table (one row per executed stage
  span, with how the span ended).
* :meth:`queue_depth_series` / :meth:`utilization_series` — step-wise
  time series straight from the per-record state snapshots.

Server lanes are assigned post-hoc (the pool tracks counts, not
identities): a min-heap of free lanes replays dispatch/release order,
so lane count equals the peak concurrency and re-used servers share
lanes deterministically.

Both frontends emit the identical schema — ``simulate(...,
recorder=...)`` and ``ClusterManager.run(recorder=...)`` differ only in
which event kinds appear (the DES never emits failure/restart/resize).
"""

from __future__ import annotations

import heapq
import json

import numpy as np

from repro.core.des.events import (
    EV_CANCEL,
    EV_COMPLETE,
    EV_DISPATCH,
    EV_RESIZE,
    EV_RESTART,
    EV_STAGE_DONE,
    EVENT_NAMES,
    EngineObserver,
    TraceEvent,
)

__all__ = ["TraceRecorder", "validate_chrome_trace"]

#: Record-tuple field offsets (see ``events.RECORD_FIELDS``).
_T, _KIND, _JOB, _STAGE, _VALUE, _QLEN, _BUSY, _FREE, _TARGET = range(9)

#: Events that end the recorded job's in-flight stage span.
_RELEASE_KINDS = (EV_STAGE_DONE, EV_COMPLETE, EV_CANCEL, EV_RESTART)


class TraceRecorder(EngineObserver):
    """Buffer engine trace records; export traces, tables and series.

    One recorder may span several runs (e.g. a policy sweep); records
    accumulate until :meth:`clear`.  Attach via
    ``simulate(..., recorder=rec)`` or
    ``ClusterManager.run(recorder=rec)``.
    """

    def __init__(self, batch_size: int = 4096):
        self.batch_size = int(batch_size)
        self.records: list[tuple] = []
        self.n_runs = 0

    # -- observer protocol ------------------------------------------------

    def on_events(self, engine, records: list[tuple]) -> None:
        self.records.extend(records)

    def on_run_end(self, engine) -> None:
        self.n_runs += 1

    # -- views ------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.records)

    def clear(self) -> None:
        self.records.clear()
        self.n_runs = 0

    def events(self) -> list[TraceEvent]:
        """Typed decode of every record (allocates; not the hot path)."""
        return [TraceEvent.from_record(r) for r in self.records]

    def counts(self) -> dict[str, int]:
        """Record count per event kind name."""
        out = dict.fromkeys(EVENT_NAMES, 0)
        for r in self.records:
            out[EVENT_NAMES[r[_KIND]]] += 1
        return out

    def queue_depth_series(self) -> np.ndarray:
        """(T, 2) array of (time, ready-queue length) after each event."""
        if not self.records:
            return np.empty((0, 2))
        return np.array([(r[_T], r[_QLEN]) for r in self.records])

    def utilization_series(self) -> np.ndarray:
        """(T, 4) array of (time, busy, free, target) after each event."""
        if not self.records:
            return np.empty((0, 4))
        return np.array(
            [(r[_T], r[_BUSY], r[_FREE], r[_TARGET]) for r in self.records]
        )

    # -- Gantt ------------------------------------------------------------

    def gantt(self) -> list[dict]:
        """Per-server stage spans: one row per dispatch→release pair.

        Rows: ``{"server", "job", "stage", "start", "end", "end_kind"}``
        with ``end_kind`` one of ``stage_done`` (survived, requeued),
        ``complete`` (success exit), ``cancel`` (early-termination
        exit), ``restart`` (failure abort — the stage's work was lost).
        Spans still open at the end of the records (only possible on a
        truncated trace) are dropped.
        """
        rows = []
        free_lanes: list[int] = []
        next_lane = 0
        open_spans: dict[int, tuple[float, int, int]] = {}  # job -> (t0, lane, stage)
        for r in self.records:
            kind = r[_KIND]
            if kind == EV_DISPATCH:
                lane = heapq.heappop(free_lanes) if free_lanes else next_lane
                if lane == next_lane:
                    next_lane += 1
                open_spans[r[_JOB]] = (r[_T], lane, r[_STAGE])
            elif kind in _RELEASE_KINDS and r[_JOB] in open_spans:
                t0, lane, stage = open_spans.pop(r[_JOB])
                heapq.heappush(free_lanes, lane)
                rows.append({
                    "server": lane,
                    "job": r[_JOB],
                    "stage": stage,
                    "start": t0,
                    "end": r[_T],
                    "end_kind": EVENT_NAMES[kind],
                })
        return rows

    # -- Chrome trace-event / Perfetto export -----------------------------

    def to_chrome_trace(self, time_scale: float = 1e6) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable).

        ``time_scale`` converts engine time units to the format's
        microseconds (default: engine time is seconds).
        """
        trace_events = [
            {"ph": "M", "pid": 0, "name": "process_name",
             "args": {"name": "repro-des"}},
        ]
        named_lanes = set()
        for row in self.gantt():
            lane = row["server"]
            if lane not in named_lanes:
                named_lanes.add(lane)
                trace_events.append({
                    "ph": "M", "pid": 0, "tid": lane, "name": "thread_name",
                    "args": {"name": f"server-{lane}"},
                })
            trace_events.append({
                "ph": "X",
                "name": f"job{row['job']}/stage{row['stage']}",
                "cat": "stage",
                "pid": 0,
                "tid": lane,
                "ts": row["start"] * time_scale,
                "dur": (row["end"] - row["start"]) * time_scale,
                "args": {"job": row["job"], "stage": row["stage"],
                         "end_kind": row["end_kind"]},
            })
        instant_kinds = (EV_RESTART, EV_RESIZE, EV_COMPLETE, EV_CANCEL)
        for r in self.records:
            kind = r[_KIND]
            if kind in instant_kinds:
                trace_events.append({
                    "ph": "i", "s": "g",
                    "name": EVENT_NAMES[kind],
                    "cat": "scheduler",
                    "pid": 0, "tid": 0,
                    "ts": r[_T] * time_scale,
                    "args": {"job": r[_JOB], "value": r[_VALUE]},
                })
            # counter tracks: queue depth and server occupancy per event
            trace_events.append({
                "ph": "C", "name": "queue_depth", "pid": 0,
                "ts": r[_T] * time_scale, "args": {"jobs": r[_QLEN]},
            })
            trace_events.append({
                "ph": "C", "name": "servers", "pid": 0,
                "ts": r[_T] * time_scale,
                "args": {"busy": r[_BUSY], "free": r[_FREE],
                         "target": r[_TARGET]},
            })
        return {
            "traceEvents": trace_events,
            "displayTimeUnit": "ms",
            "otherData": {
                "schema": "repro.obs/chrome-trace/v1",
                "runs": self.n_runs,
                "records": len(self.records),
                "counts": self.counts(),
            },
        }

    def write_chrome_trace(self, path: str, time_scale: float = 1e6) -> dict:
        """Export :meth:`to_chrome_trace` to ``path``; returns the object."""
        obj = self.to_chrome_trace(time_scale)
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj


def validate_chrome_trace(obj: dict) -> dict:
    """Validate a trace object against the Chrome trace-event schema.

    Checks the subset Perfetto needs to load the file: the
    ``traceEvents`` array, per-phase required keys, non-negative
    timestamps/durations.  Raises :class:`ValueError` on the first
    violation; returns ``{"events": n, "by_phase": {...}}`` on success.
    """
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"), list):
        raise ValueError("trace must be an object with a traceEvents array")
    by_phase: dict[str, int] = {}
    required = {
        "X": ("name", "ts", "dur", "pid", "tid"),
        "i": ("name", "ts", "s"),
        "C": ("name", "ts", "args"),
        "M": ("name", "args"),
    }
    for i, ev in enumerate(obj["traceEvents"]):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"traceEvents[{i}]: not an event object")
        ph = ev["ph"]
        if ph not in required:
            raise ValueError(f"traceEvents[{i}]: unknown phase {ph!r}")
        for key in required[ph]:
            if key not in ev:
                raise ValueError(f"traceEvents[{i}] (ph={ph}): missing {key!r}")
        if "ts" in ev and not ev["ts"] >= 0:
            raise ValueError(f"traceEvents[{i}]: negative ts {ev['ts']}")
        if ph == "X" and not ev["dur"] >= 0:
            raise ValueError(f"traceEvents[{i}]: negative dur {ev['dur']}")
        by_phase[ph] = by_phase.get(ph, 0) + 1
    return {"events": len(obj["traceEvents"]), "by_phase": by_phase}
