"""``python -m repro.obs.report`` — replay, trace, measure, export.

Replays a synthetic Philly-trace workload (``repro.core.trace``)
through the cluster manager with a :class:`~repro.obs.TraceRecorder`
and a :class:`~repro.obs.MetricsRegistry` attached, then:

* writes ``trace.json`` — Chrome trace-event JSON; open it at
  https://ui.perfetto.dev (or ``chrome://tracing``) for per-server
  Gantt tracks plus queue-depth / server-occupancy counters;
* writes ``metrics.json`` — the metrics snapshot (sojourn percentiles,
  busy fraction, wasted work, restart counts), the workload-cache
  stats, profiling spans (with ``--profile``), and the trace summary;
* prints a text report.

``--validate`` checks the exported trace against the schema (CI runs
this).  ``--bench-overhead`` replays the same workload with tracing
off vs on, asserts the sojourn results agree to 1e-9, and reports the
batched observer dispatch overhead (acceptance bar: <= 10% on a
>= 100k-event replay — use ``--jobs 20000`` or more to get there).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.cluster.faults import FaultConfig
from repro.cluster.manager import ClusterManager, TrainingJob
from repro.core import policies, trace
from repro.obs import metrics as obs_metrics
from repro.obs import profiling
from repro.obs.recorder import TraceRecorder, validate_chrome_trace

__all__ = ["main", "replay", "bench_overhead"]


def _make_jobs(args) -> list:
    """Load-matched synthetic Philly trace (same scaling as benchmarks)."""
    from repro.configs.paper_workloads import TRACE

    duration = args.duration_days
    if duration is None:
        duration = TRACE.duration_days * (args.jobs / TRACE.n_jobs)
    rng = np.random.default_rng(args.seed)
    return trace.synthesize_trace(rng, n_jobs=args.jobs, duration_days=duration)


def _manager(specs, args, fresh_seed: int = 0) -> ClusterManager:
    fault_cfg = None
    if args.faults:
        # MTBF sized so the per-job abort interval stays well above the
        # Philly-scale stage durations (hours): jobs retry a handful of
        # times, they don't thrash.
        fault_cfg = FaultConfig(
            mtbf_hours=500.0, restart_overhead=60.0,
            straggler_prob=0.05, straggler_slowdown=4.0,
        )
    return ClusterManager(
        [TrainingJob(spec=s) for s in specs],
        args.servers,
        policy=args.policy,
        fault_cfg=fault_cfg,
        nodes_per_server=4 if args.faults else 1,
        rng=np.random.default_rng(args.seed + fresh_seed),
        resize_events=args.resize,
    )


def replay(specs, args, recorder=None, registry=None):
    """One cluster-manager replay; returns its :class:`ClusterResult`."""
    return _manager(specs, args).run(recorder=recorder, metrics=registry)


def bench_overhead(specs, args, repeats: int = 3) -> dict:
    """Traced-vs-untraced wall clock + bit-level result agreement."""

    def timed(traced: bool):
        times, results, n_events = [], [], 0
        for _ in range(repeats):
            rec = TraceRecorder() if traced else None
            t0 = time.perf_counter()
            res = replay(specs, args, recorder=rec)
            times.append(time.perf_counter() - t0)
            results.append(res.mean_sojourn_successful)
            if rec is not None:
                n_events = len(rec)
                rec.clear()
        return float(np.median(times)), results, n_events

    t_off, r_off, _ = timed(traced=False)
    t_on, r_on, n_events = timed(traced=True)
    # identical seeds => identical runs; tracing must not perturb them
    err = max(
        abs(a - b) / max(abs(b), 1e-300) for a, b in zip(r_on, r_off)
    )
    assert err <= 1e-9, f"tracing perturbed sojourn results: relerr={err}"
    return {
        "events": n_events,
        "repeats": repeats,
        "untraced_s": t_off,
        "traced_s": t_on,
        "overhead_pct": 100.0 * (t_on / t_off - 1.0) if t_off > 0 else 0.0,
        "max_relerr": err,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--jobs", type=int, default=300)
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--policy", default="rank",
                    choices=["rank", "serpt", "sr", "fifo"])
    ap.add_argument("--duration-days", type=float, default=None,
                    help="trace span (default: load-matched to the paper trace)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", action="store_true",
                    help="inject node failures + stragglers")
    ap.add_argument("--resize", type=float, nargs=2, action="append",
                    metavar=("T", "TARGET"), default=None,
                    help="elastic resize event (repeatable)")
    ap.add_argument("--batch-size", type=int, default=4096,
                    help="observer dispatch batch")
    ap.add_argument("--out", default=os.path.join("artifacts", "obs"))
    ap.add_argument("--validate", action="store_true",
                    help="validate the exported trace JSON against the schema")
    ap.add_argument("--bench-overhead", action="store_true",
                    help="measure traced-vs-untraced wall-clock overhead")
    ap.add_argument("--profile", action="store_true",
                    help="enable kernel/cache profiling spans")
    args = ap.parse_args(argv)
    args.resize = [(t, int(w)) for t, w in args.resize] if args.resize else None

    if args.profile:
        profiling.enable()
    os.makedirs(args.out, exist_ok=True)
    specs = _make_jobs(args)

    recorder = TraceRecorder(batch_size=args.batch_size)
    registry = obs_metrics.MetricsRegistry()
    t0 = time.perf_counter()
    res = replay(specs, args, recorder=recorder, registry=registry)
    wall = time.perf_counter() - t0

    trace_path = os.path.join(args.out, "trace.json")
    trace_obj = recorder.write_chrome_trace(trace_path)
    summary = {
        "jobs": args.jobs, "servers": args.servers, "policy": args.policy,
        "faults": bool(args.faults), "records": len(recorder),
        "counts": recorder.counts(), "wall_s": wall,
    }
    if args.validate:
        summary["trace_schema"] = validate_chrome_trace(trace_obj)
        print(f"trace schema OK: {summary['trace_schema']}")
    if args.bench_overhead:
        summary["overhead"] = bench_overhead(specs, args)

    # fold profiling spans (default registry) into the run registry dump
    extra = {
        "run": summary,
        "workload_cache": policies.cache_stats(),
    }
    if args.profile:
        extra["profiling"] = obs_metrics.get_registry().snapshot()
    metrics_path = os.path.join(args.out, "metrics.json")
    registry.to_json(metrics_path, **extra)

    qd = recorder.queue_depth_series()
    print(f"replayed {args.jobs} jobs / {args.servers} servers "
          f"({args.policy}) in {wall:.2f}s -> {len(recorder)} trace records")
    print(f"  success {res.n_success}/{res.n_jobs}  "
          f"makespan {res.makespan:.3f}  restarts {res.restarts}")
    if qd.size:
        print(f"  queue depth: peak {int(qd[:, 1].max())}  "
              f"mean {qd[:, 1].mean():.2f}")
    print(obs_metrics.format_snapshot(registry.snapshot(), title="run metrics"))
    if args.profile:
        print(obs_metrics.format_snapshot(
            obs_metrics.get_registry().snapshot(), title="profiling"))
    if args.bench_overhead:
        ov = summary["overhead"]
        print(f"== overhead ==\n  {ov['events']} events: untraced "
              f"{ov['untraced_s']:.3f}s traced {ov['traced_s']:.3f}s "
              f"-> +{ov['overhead_pct']:.2f}% (max relerr {ov['max_relerr']:.2e})")
    print(f"wrote {trace_path} (load at https://ui.perfetto.dev) and "
          f"{metrics_path}")
    print(json.dumps(summary["counts"]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
