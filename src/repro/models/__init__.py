"""Model plane: the 10 assigned architectures as one composable stack.

* :mod:`repro.models.config`      — ModelConfig covering all families
* :mod:`repro.models.init`        — ParamSpec trees + materialization
* :mod:`repro.models.layers`      — norms, rope, MLP, embeddings
* :mod:`repro.models.attention`   — GQA/qk-norm/SWA/cross attention
* :mod:`repro.models.moe`         — router + dispatch + grouped FFN
* :mod:`repro.models.ssm`         — Mamba-2 (SSD) mixer
* :mod:`repro.models.transformer` — block assembly, scan, fwd + decode
* :mod:`repro.models.kvcache`     — serving caches (full/SWA/SSM)
* :mod:`repro.models.frontends`   — audio/vision stub embeddings
"""

from repro.models.config import ModelConfig  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    init_params,
    lm_loss,
    param_logical,
)
