"""One config dataclass spanning all assigned architecture families.

Families:
  dense  — decoder-only GQA transformer (qwen3, granite, llama3)
  moe    — dense + mixture-of-experts FFN (mixtral, kimi-k2)
  ssm    — pure Mamba-2 stack (mamba2-1.3b)
  hybrid — Jamba-style attn:mamba interleave + periodic MoE (jamba)
  encdec — encoder-decoder with cross attention (seamless-m4t; audio
           frontend is a stub producing frame embeddings)
  vlm    — decoder + periodic cross-attention to image tokens
           (llama-3.2-vision; patch embeddings stubbed)
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["ModelConfig"]

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = True

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_period: int = 1  # a layer l is MoE iff l % moe_period == moe_offset
    moe_offset: int = 0
    moe_group: int = 1024  # tokens per dispatch group (einsum mode)
    moe_ep: str = "auto"  # "auto" | "ep" | "tp": expert-parallel vs TP-in-expert

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # --- hybrid (Jamba) ------------------------------------------------------
    attn_period: int = 0  # within each period, position attn_offset is attention
    attn_offset: int = 4

    # --- encoder-decoder -----------------------------------------------------
    n_enc_layers: int = 0
    frontend_frames: int = 0  # stub audio frontend sequence length

    # --- vlm -----------------------------------------------------------------
    cross_attn_period: int = 0  # one cross-attn layer per period (position 0)
    num_image_tokens: int = 0

    # --- numerics / execution ------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # "none" | "full" | "dots"
    scan_layers: bool = True
    vocab_pad_multiple: int = 256
    attn_impl: str = "auto"
    moe_impl: str = "auto"
    ssd_impl: str = "auto"
    attn_block_q: int = 128
    attn_block_k: int = 128
    logit_chunk: int = 0  # 0 = unchunked cross-entropy; >0 = vocab chunking

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm" and self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # -- derived -------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def is_moe_layer(self, layer: int) -> bool:
        if self.n_experts == 0:
            return False
        return layer % self.moe_period == self.moe_offset

    def is_attn_layer(self, layer: int) -> bool:
        """hybrid only: which positions in the period are attention."""
        if self.family != "hybrid":
            return True
        return layer % self.attn_period == self.attn_offset

    # -- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----------

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (embedding + blocks [+ head])."""
        d, v = self.d_model, self.padded_vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d

        def attn_params() -> int:
            return d * self.n_heads * self.hd * 2 + d * self.n_kv_heads * self.hd * 2

        def mlp_params() -> int:
            return 3 * d * self.d_ff

        def moe_params(active: bool) -> int:
            e = self.top_k if active else self.n_experts
            return 3 * d * self.d_ff * e + d * self.n_experts  # + router

        def mamba_params() -> int:
            din, g, n, h = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            return (
                d * din * 2  # w_x, w_z
                + d * 2 * g * n  # w_BC
                + d * h  # w_dt
                + self.ssm_conv * (din + 2 * g * n)  # depthwise conv
                + 3 * h  # A_log, dt_bias, D
                + din  # gated norm
                + din * d  # out_proj
            )

        n_dec = self.n_layers
        for l in range(n_dec):
            if self.family == "ssm":
                total += mamba_params()
                continue
            if self.family == "hybrid":
                total += attn_params() if self.is_attn_layer(l) else mamba_params()
            elif self.family == "vlm" and self.cross_attn_period and (
                l % self.cross_attn_period == 0
            ):
                total += 2 * attn_params()  # self + gated cross
            else:
                total += attn_params()
            if self.is_moe_layer(l):
                total += moe_params(active_only)
            elif self.d_ff:
                total += mlp_params()
        if self.family == "encdec":
            for _ in range(self.n_enc_layers):
                total += attn_params() + mlp_params()  # encoder self-attn blocks
            total += n_dec * attn_params()  # decoder cross-attention
        return total
