"""Mamba-2 (SSD) mixer block.

Structure (arXiv:2405.21060): in-projections to x (d_inner), z (gate),
B/C (per-group state projections) and dt (per-head step size); short
depthwise causal conv on x and B/C; softplus dt; the SSD scan
(:mod:`repro.kernels.ssd_scan`); gated RMSNorm; out-projection.

The single fused conv over concat([x, B, C]) of the reference CUDA code
is split into two depthwise convs (x | BC) so the d_inner axis shards
cleanly over "model" while the small BC channels stay replicated —
depthwise convs are channelwise, so this is mathematically identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_decode_step
from repro.models.config import ModelConfig
from repro.models.init import ParamSpec
from repro.models.layers import rms_norm
from repro.parallel.sharding import ShardingCtx

__all__ = ["ssm_specs", "ssm_apply", "ssm_decode", "ssm_cache_shape"]


def ssm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    din = cfg.d_inner
    g, n, h, kc = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    bc = 2 * g * n
    return {
        "w_x": ParamSpec((d, din), ("embed", "conv_dim"), dtype=cfg.pdtype),
        "w_z": ParamSpec((d, din), ("embed", "conv_dim"), dtype=cfg.pdtype),
        "w_bc": ParamSpec((d, bc), ("embed", None), dtype=cfg.pdtype),
        "w_dt": ParamSpec((d, h), ("embed", "ssm_heads"), dtype=cfg.pdtype),
        "conv_x": ParamSpec((kc, din), (None, "conv_dim"), dtype=cfg.pdtype),
        "conv_bc": ParamSpec((kc, bc), (None, None), dtype=cfg.pdtype),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "norm": ParamSpec((din,), ("conv_dim",), init="ones", dtype=jnp.float32),
        "out": ParamSpec((din, d), ("conv_dim", "embed"), dtype=cfg.pdtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, state=None):
    """x (B, S, C), w (K, C) — causal depthwise conv via shifted adds.

    K is tiny (4), so K shifted elementwise multiply-adds beat a real conv
    on TPU.  ``state`` (B, K-1, C) holds the trailing inputs for decode
    chaining; returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
        for i in range(k)
    )
    new_state = xp[:, -(k - 1) :, :] if k > 1 else pad[:, :0]
    return y, new_state


def ssm_cache_shape(cfg: ModelConfig, batch: int) -> dict:
    """Decode cache pytree shapes for one layer."""
    return {
        "conv_x": (batch, cfg.ssm_conv - 1, cfg.d_inner),
        "conv_bc": (batch, cfg.ssm_conv - 1, 2 * cfg.ssm_groups * cfg.ssm_state),
        "state": (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
    }


def _projections(p, x, cfg: ModelConfig):
    xs = x @ p["w_x"]  # (B, S, din)
    z = x @ p["w_z"]
    bc = x @ p["w_bc"]  # (B, S, 2GN)
    dt_raw = x @ p["w_dt"]  # (B, S, H)
    return xs, z, bc, dt_raw


def _postprocess(p, y, z, cfg: ModelConfig, ctx: ShardingCtx, *, decode=False):
    b = y.shape[0]
    if decode:
        y = y.reshape(b, 1, cfg.d_inner)
    else:
        y = y.reshape(b, y.shape[1], cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)  # gated
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = y @ p["out"]
    return out


def ssm_apply(
    p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
    *, return_cache: bool = False,
):
    """Full-sequence SSD mixer (training; prefill with ``return_cache``)."""
    b, s, _ = x.shape
    g, n, h, pd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    xs_raw, z, bc_raw, dt_raw = _projections(p, x, cfg)
    xs_raw = ctx.constrain(xs_raw, ("batch", "seq", "act_mlp"))
    xs, conv_x_tail = _causal_depthwise_conv(xs_raw, p["conv_x"])
    bc, conv_bc_tail = _causal_depthwise_conv(bc_raw, p["conv_bc"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32)).astype(x.dtype)
    Bm = bc[..., : g * n].reshape(b, s, g, n)
    Cm = bc[..., g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xh = xs.reshape(b, s, h, pd)
    chunk = min(cfg.ssm_chunk, s)
    y, state = ssd_scan(xh, dt, A, Bm, Cm, p["D"], chunk=chunk, impl=cfg.ssd_impl)
    out = _postprocess(p, y, z, cfg, ctx)
    if return_cache:
        cache = {
            "conv_x": conv_x_tail.astype(cfg.dtype),
            "conv_bc": conv_bc_tail.astype(cfg.dtype),
            "state": state,
        }
        return out, cache
    return out


def ssm_decode(
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cache: dict,
    cfg: ModelConfig,
    ctx: ShardingCtx,
) -> tuple[jax.Array, dict]:
    """One-token SSD recurrence; O(1) state instead of a KV cache."""
    b = x.shape[0]
    g, n, h, pd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    xs, z, bc, dt_raw = _projections(p, x, cfg)
    xs, conv_x = _causal_depthwise_conv(xs, p["conv_x"], cache["conv_x"])
    bc, conv_bc = _causal_depthwise_conv(bc, p["conv_bc"], cache["conv_bc"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32)).astype(x.dtype)
    Bm = bc[:, 0, : g * n].reshape(b, g, n)
    Cm = bc[:, 0, g * n :].reshape(b, g, n)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])

    # stored state layout: (B, H, N, P)
    y, state = ssd_decode_step(
        xs[:, 0].reshape(b, h, pd), dt, A, Bm, Cm, p["D"],
        cache["state"].astype(jnp.float32),
    )
    new_cache = {
        "conv_x": conv_x.astype(cache["conv_x"].dtype),
        "conv_bc": conv_bc.astype(cache["conv_bc"].dtype),
        "state": state,
    }
    out = _postprocess(p, y, z[:, 0][:, None, :] if z.ndim == 2 else z, cfg, ctx, decode=True)
    return out, new_cache
