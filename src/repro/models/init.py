"""Parameter spec trees: shapes + logical sharding axes + initializers.

Models are spec-first: every module contributes a pytree of
:class:`ParamSpec`; ``materialize`` turns a spec tree into arrays (on
host or directly sharded via ``jax.jit`` out_shardings), and
``logical_tree`` extracts the logical-axes pytree consumed by
:mod:`repro.parallel.sharding`.

Initializers are minimal (normal / zeros / ones / constant scaled
truncated-normal fan-in), enough to train the smoke/100M examples.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ParamSpec", "materialize", "logical_tree", "abstract_tree"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "fan_in"  # fan_in | normal | zeros | ones | const
    scale: float = 1.0
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"shape {self.shape} vs logical {self.logical}")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "const":
        return jnp.full(spec.shape, spec.scale, spec.dtype)
    if spec.init == "normal":
        std = spec.scale
    elif spec.init == "fan_in":
        fan_in = spec.shape[0] if len(spec.shape) == 1 else math.prod(spec.shape[:-1])
        # stacked layers: the leading "layers"/"periods" dim is not fan-in
        if spec.logical and spec.logical[0] in ("layers", "periods") and len(spec.shape) > 2:
            fan_in = math.prod(spec.shape[1:-1])
        std = spec.scale / math.sqrt(max(fan_in, 1))
    else:
        raise ValueError(f"unknown init {spec.init!r}")
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


def materialize(spec_tree: Any, key: jax.Array) -> Any:
    """Instantiate every ParamSpec leaf with a derived PRNG key."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(s, k) for s, k in zip(leaves, keys)]
    )


def logical_tree(spec_tree: Any) -> Any:
    """Pytree of logical-axis tuples (same structure as the params)."""
    return jax.tree.map(lambda s: s.logical, spec_tree, is_leaf=_is_spec)


def abstract_tree(spec_tree: Any) -> Any:
    """Pytree of ShapeDtypeStructs (for dry-run lowering without allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=_is_spec
    )
