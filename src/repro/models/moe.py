"""Mixture-of-Experts FFN: top-k router, capacity dispatch, grouped GEMM.

TPU-idiomatic dispatch (no CUDA scatter kernels). Two modes:

* ``einsum`` (default, small/medium E): tokens are processed in groups of
  ``moe_group``; per group the (g, E, cap) one-hot dispatch tensor is the
  product of the expert one-hot and the slot one-hot, contracted on the
  MXU.  Capacity is per-group (cap = cf·k·g/E), so the dispatch tensor is
  O(cf·k·g²) per group regardless of E.
* ``scatter`` (huge E, e.g. Kimi-K2's 384 experts): tokens are placed via
  ``.at[slot].add`` into the (E·cap, D) buffer and combined back with a
  gather — O(N·D) memory, no big one-hots.  XLA lowers this to
  (sorted) scatters which GSPMD shards on the token axis.

Sharding: token-side tensors stay on ("pod","data"); the expert buffer is
resharded to "model" (EP) before the grouped GEMM — that reshard is the
MoE all-to-all analogue and shows up as such in the dry-run HLO.

Load-balancing aux loss follows Switch/Mixtral: E · Σ_e f_e · P_e.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.moe_gemm.ops import moe_ffn
from repro.models.config import ModelConfig
from repro.models.init import ParamSpec
from repro.parallel.sharding import ShardingCtx

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", "experts"), dtype=jnp.float32),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), dtype=cfg.pdtype),
        "wu": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), dtype=cfg.pdtype),
        "wd": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed"), dtype=cfg.pdtype),
    }


def _route(p, xt, cfg: ModelConfig):
    """Router: top-k choices, renormalized gates, aux loss."""
    e, k = cfg.n_experts, cfg.top_k
    logits = xt.astype(jnp.float32) @ p["router"]  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, choice = jax.lax.top_k(probs, k)  # (N, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    top1 = jax.nn.one_hot(choice[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(top1.mean(0) * probs.mean(0)) * cfg.router_aux_weight
    return choice, gate_vals, aux


def _slot_positions(choice: jax.Array, e: int, cap: int):
    """Position of each (token, k) pair within its expert's buffer (FIFO)."""
    n, k = choice.shape
    flat = jax.nn.one_hot(choice.reshape(-1), e, dtype=jnp.int32)  # (N*k, E)
    pos = (jnp.cumsum(flat, axis=0) - flat).reshape(n, k, e)
    pos = jnp.take_along_axis(pos, choice[..., None], axis=-1)[..., 0]  # (N, k)
    keep = pos < cap
    return pos, keep


def _moe_einsum(p, xt, choice, gate_vals, cfg: ModelConfig, ctx: ShardingCtx):
    n, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    g = min(cfg.moe_group, n)
    if n % g:
        g = n  # irregular token counts (smoke tests): one group
    ng = n // g
    cap = max(int(cfg.capacity_factor * k * g / e) + 7 & ~7, 8)

    # group axis ng carries the token sharding ("batch" -> pod/data);
    # expert axis carries EP ("experts" -> model).  The dispatched buffer
    # xe is sharded over BOTH, so per-chip dispatch memory is
    # O(tokens_per_chip * k * cf * D / model_axis) — the GSPMD analogue
    # of all-to-all MoE dispatch (the reshard shows up in the dry-run HLO).
    xg = xt.reshape(ng, g, d)
    cg = choice.reshape(ng, g, k)
    wg_ = gate_vals.reshape(ng, g, k)
    pos, keep = jax.vmap(lambda c: _slot_positions(c, e, cap))(cg)  # per group

    eh = jax.nn.one_hot(cg, e, dtype=xt.dtype)  # (ng, g, k, E)
    ch = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=xt.dtype)  # OOB -> 0
    disp = jnp.einsum("nske,nskc->nsec", eh, ch)  # (ng, g, E, cap)
    disp = ctx.constrain(disp, ("batch", None, "experts", None))
    comb = jnp.einsum("nske,nskc,nsk->nsec", eh, ch, wg_.astype(xt.dtype))
    comb = ctx.constrain(comb, ("batch", None, "experts", None))

    xe = jnp.einsum("nsec,nsd->necd", disp, xg)  # (ng, E, cap, D)
    xe = ctx.constrain(xe, ("batch", "experts", None, None))
    ye = jax.vmap(lambda xb: moe_ffn(xb, p["wg"], p["wu"], p["wd"], impl=cfg.moe_impl))(xe)
    # NOTE: ye is deliberately NOT resharded here.  In TP-within-expert
    # mode (few experts) the down-projection leaves ye partial-summed over
    # "model"; constraining it would force an all-reduce of the (E, cap)
    # capacity view — 2.5x (cf·k) more bytes than reducing the combined
    # token view.  Deferring lets GSPMD reduce after the combine einsum.
    # (§Perf hillclimb B: -37% collective bytes on mixtral train_4k.)
    out = jnp.einsum("nsec,necd->nsd", comb, ye)
    return out.reshape(n, d)


def moe_apply(
    p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss scalar)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    choice, gate_vals, aux = _route(p, xt, cfg)
    out = _moe_einsum(p, xt, choice, gate_vals, cfg, ctx)
    out = ctx.constrain(out.reshape(b, s, d).astype(x.dtype), ("batch", "seq", "act_embed"))
    return out, aux
