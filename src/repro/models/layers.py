"""Shared layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, cross-entropy."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.init import ParamSpec
from repro.parallel.sharding import ShardingCtx

__all__ = [
    "rms_norm",
    "rope",
    "mlp_specs",
    "mlp_apply",
    "embed_specs",
    "cross_entropy",
]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm in f32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding on the last dim; x (..., S, H, D), positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wg": ParamSpec((d, f), ("embed", "mlp"), dtype=cfg.pdtype),
        "wu": ParamSpec((d, f), ("embed", "mlp"), dtype=cfg.pdtype),
        "wd": ParamSpec((f, d), ("mlp", "embed"), dtype=cfg.pdtype),
    }


def mlp_apply(p: dict, x: jax.Array, ctx: ShardingCtx) -> jax.Array:
    h_g = x @ p["wg"]
    h_u = x @ p["wu"]
    h_g = ctx.constrain(h_g, ("batch", "seq", "act_mlp"))
    act = (jax.nn.silu(h_g.astype(jnp.float32)) * h_u.astype(jnp.float32)).astype(
        x.dtype
    )
    out = act @ p["wd"]
    return ctx.constrain(out, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> dict:
    v, d = cfg.padded_vocab, cfg.d_model
    specs = {
        "tok": ParamSpec((v, d), ("vocab", "embed"), scale=0.02, init="normal",
                         dtype=cfg.pdtype),
        "final_norm": ParamSpec((d,), (None,), init="ones", dtype=jnp.float32),
    }
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((d, v), ("embed", "vocab"), dtype=cfg.pdtype)
    return specs


def embed_tokens(p: dict, tokens: jax.Array, cfg: ModelConfig, ctx: ShardingCtx):
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.dtype)
    return ctx.constrain(x, ("batch", "seq", "act_embed"))


def unembed(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx) -> jax.Array:
    w = p["head"] if "head" in p else p["tok"].T
    logits = (x @ w).astype(jnp.float32)
    return ctx.constrain(logits, ("batch", "seq", "act_vocab"))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(
    logits: jax.Array, labels: jax.Array, valid: jax.Array | None = None
) -> jax.Array:
    """Mean token cross-entropy in f32; labels < 0 or ~valid are masked."""
    if valid is None:
        valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), lab[..., None], axis=-1
    )[..., 0]
    nll = (lse - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def chunked_cross_entropy(
    x: jax.Array,            # (B, S, D) final hidden states
    w: jax.Array,            # (D, V) unembedding
    labels: jax.Array,       # (B, S)
    valid: jax.Array | None,
    chunk: int,
) -> jax.Array:
    """Cross-entropy without materializing the full (B, S, V) logits.

    Streams the vocab dim in chunks of ``chunk``: accumulates a running
    logsumexp and gathers the gold logit on the fly.  Memory-roofline
    optimization for huge-vocab models (llama3 128k, kimi 160k, seamless
    256k); see EXPERIMENTS.md §Perf.
    """
    if valid is None:
        valid = labels >= 0
    b, s, d = x.shape
    v = w.shape[-1]
    if v % chunk:
        raise ValueError(f"vocab {v} not divisible by chunk {chunk}")
    lab = jnp.maximum(labels, 0)

    def body(carry, i):
        m, l, gold = carry
        wi = jax.lax.dynamic_slice_in_dim(w, i * chunk, chunk, axis=1)
        lg = (x @ wi).astype(jnp.float32)  # (B, S, chunk)
        m_new = jnp.maximum(m, lg.max(axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(axis=-1)
        in_chunk = (lab >= i * chunk) & (lab < (i + 1) * chunk)
        local_idx = (lab - i * chunk).clip(0, chunk - 1)
        local = jnp.take_along_axis(lg, local_idx[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_chunk, local, gold)
        return (m_new, l, gold), None

    m0 = jnp.full((b, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, s), jnp.float32)
    g0 = jnp.zeros((b, s), jnp.float32)
    (m, l, gold), _ = jax.lax.scan(body, (m0, l0, g0), jnp.arange(v // chunk))
    nll = (m + jnp.log(jnp.maximum(l, 1e-30)) - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
