"""Modality frontend stubs (per the assignment: [audio]/[vlm] entries
specify the transformer backbone only; the frontend supplies precomputed
frame/patch embeddings).

``input_specs`` in :mod:`repro.configs.shapes` uses these to size the
ShapeDtypeStruct stand-ins; the smoke tests and examples use the random
embedding generators below."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["audio_frames_stub", "image_embeds_stub", "frontend_shapes"]


def frontend_shapes(cfg: ModelConfig, batch: int) -> dict:
    """Extra model inputs (beyond tokens) per family, as shape dicts."""
    if cfg.family == "encdec":
        return {"enc_frames": (batch, cfg.frontend_frames, cfg.d_model)}
    if cfg.family == "vlm":
        return {"image_embeds": (batch, cfg.num_image_tokens, cfg.d_model)}
    return {}


def audio_frames_stub(key: jax.Array, cfg: ModelConfig, batch: int) -> jnp.ndarray:
    """Precomputed speech-frame embeddings (e.g. 50 Hz fbank->conv stack)."""
    return 0.02 * jax.random.normal(
        key, (batch, cfg.frontend_frames, cfg.d_model), jnp.float32
    ).astype(cfg.dtype)


def image_embeds_stub(key: jax.Array, cfg: ModelConfig, batch: int) -> jnp.ndarray:
    """Precomputed ViT patch embeddings (e.g. 560px/14 -> 1601 tokens)."""
    return 0.02 * jax.random.normal(
        key, (batch, cfg.num_image_tokens, cfg.d_model), jnp.float32
    ).astype(cfg.dtype)
