"""Block assembly for all architecture families: specs, forward, decode.

Layer stacking: homogeneous blocks are stacked along a leading "layers"
axis and iterated with ``jax.lax.scan`` — HLO size stays O(1) in depth
(critical for 61-layer Kimi lowered at 512 devices).  Heterogeneous
families scan over *periods*:

* hybrid (Jamba): period of ``attn_period`` (8) positions; position
  ``attn_offset`` (4) is attention, the rest Mamba; odd positions carry
  MoE FFNs, even positions dense MLPs (matching Jamba's 1:7 attn:mamba
  and every-2-layers MoE).
* vlm (Llama-3.2-Vision): period of ``cross_attn_period`` (5); position 0
  is a gated cross-attention block into the (stubbed) image tokens.
* encdec (Seamless): a bidirectional encoder stack over stub audio-frame
  embeddings, then a decoder stack of (self-attn, cross-attn, MLP).

Three execution modes share the block code: ``train`` (full sequence),
``prefill`` (full sequence, emits the serving cache), ``decode`` (one
token, consumes/updates the cache).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.init import ParamSpec, abstract_tree, logical_tree, materialize
from repro.models.layers import (
    chunked_cross_entropy,
    cross_entropy,
    embed_specs,
    embed_tokens,
    mlp_apply,
    mlp_specs,
    rms_norm,
    unembed,
)
from repro.parallel.sharding import ShardingCtx

__all__ = [
    "param_specs",
    "param_logical",
    "init_params",
    "abstract_params",
    "forward",
    "lm_loss",
    "decode_step",
    "init_cache",
    "prefill",
]


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------


def _norm_spec(cfg):
    return ParamSpec((cfg.d_model,), (None,), init="ones", dtype=jnp.float32)


def _attn_block_specs(cfg: ModelConfig, moe: bool, cross: bool = False) -> dict:
    specs = {
        "ln1": _norm_spec(cfg),
        "attn": attn.attn_specs(cfg, cross=cross),
    }
    if cfg.d_ff or moe:
        specs["ln2"] = _norm_spec(cfg)
        specs["ffn"] = moe_mod.moe_specs(cfg) if moe else mlp_specs(cfg)
    return specs


def _mamba_block_specs(cfg: ModelConfig, ffn: str | None = None) -> dict:
    specs = {"ln1": _norm_spec(cfg), "mamba": ssm_mod.ssm_specs(cfg)}
    if ffn == "mlp":
        specs["ln2"] = _norm_spec(cfg)
        specs["ffn"] = mlp_specs(cfg)
    elif ffn == "moe":
        specs["ln2"] = _norm_spec(cfg)
        specs["ffn"] = moe_mod.moe_specs(cfg)
    return specs


def _stack_specs(spec: Any, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacked leading dim to every ParamSpec leaf."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), (axis_name, *s.logical), s.init, s.scale, s.dtype),
        spec,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _period_structure(cfg: ModelConfig) -> list[tuple[str, str | None]]:
    """For period-scanned families: list of (mixer, ffn) per position."""
    if cfg.family == "hybrid":
        out = []
        for pos in range(cfg.attn_period):
            mixer = "attn" if pos == cfg.attn_offset else "mamba"
            ffn = "moe" if cfg.is_moe_layer(pos) else "mlp"
            out.append((mixer, ffn))
        return out
    if cfg.family == "vlm":
        out = [("cross", "mlp")]
        out += [("attn", "mlp")] * (cfg.cross_attn_period - 1)
        return out
    raise ValueError(cfg.family)


def param_specs(cfg: ModelConfig) -> dict:
    specs: dict = {"embed": embed_specs(cfg)}
    fam = cfg.family
    if fam in ("dense", "moe"):
        block = _attn_block_specs(cfg, moe=cfg.n_experts > 0)
        specs["layers"] = _stack_specs(block, cfg.n_layers)
    elif fam == "ssm":
        specs["layers"] = _stack_specs(_mamba_block_specs(cfg), cfg.n_layers)
    elif fam in ("hybrid", "vlm"):
        period = _period_structure(cfg)
        n_periods = cfg.n_layers // len(period)
        if cfg.n_layers % len(period):
            raise ValueError(f"{cfg.n_layers} layers not divisible by period {len(period)}")
        pos_specs = {}
        for i, (mixer, ffn) in enumerate(period):
            if mixer == "mamba":
                blk = _mamba_block_specs(cfg, ffn)
            elif mixer == "cross":
                blk = _attn_block_specs(cfg, moe=False, cross=True)
            else:
                blk = _attn_block_specs(cfg, moe=(ffn == "moe"))
            pos_specs[f"pos{i}"] = blk
        specs["periods"] = _stack_specs(pos_specs, n_periods, "periods")
    elif fam == "encdec":
        enc_block = _attn_block_specs(cfg, moe=False)
        dec_block = _attn_block_specs(cfg, moe=False)
        dec_block["ln_x"] = _norm_spec(cfg)
        dec_block["xattn"] = attn.attn_specs(cfg)
        specs["enc_layers"] = _stack_specs(enc_block, cfg.n_enc_layers)
        specs["layers"] = _stack_specs(dec_block, cfg.n_layers)
        specs["enc_norm"] = _norm_spec(cfg)
    else:
        raise ValueError(fam)
    return specs


def param_logical(cfg: ModelConfig):
    return logical_tree(param_specs(cfg))


def init_params(cfg: ModelConfig, key: jax.Array):
    return materialize(param_specs(cfg), key)


def abstract_params(cfg: ModelConfig):
    return abstract_tree(param_specs(cfg))


# ---------------------------------------------------------------------------
# Blocks (mode: train | prefill | decode)
# ---------------------------------------------------------------------------


def _apply_mixer(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    mixer: str,
    mode: str,
    cache: dict | None,
    pos: jax.Array | None,
    positions: jax.Array | None,
    memory: tuple | None,
    window: int | None,
    causal: bool,
    sp: bool = False,
):
    """Dispatch one mixer; returns (out, new_cache_entry)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        if mode == "decode":
            out, k_c, v_c = attn.attn_decode(
                p["attn"], h, cache["k"], cache["v"], pos, cfg, ctx,
                ring=cfg.sliding_window is not None, sp=sp,
            )
            new_cache = dict(cache, k=k_c, v=v_c)
            return out, new_cache
        out = attn.attn_apply(
            p["attn"], h, cfg, ctx, positions, causal=causal, window=window
        )
        if mode == "prefill":
            k, v = attn._project_kv(p["attn"], h, cfg, ctx, positions)
            return out, {"k": k, "v": v}
        return out, None
    if mixer == "mamba":
        if mode == "decode":
            out, new_cache = ssm_mod.ssm_decode(p["mamba"], h, cache, cfg, ctx)
            return out, new_cache
        if mode == "prefill":
            return ssm_mod.ssm_apply(p["mamba"], h, cfg, ctx, return_cache=True)
        return ssm_mod.ssm_apply(p["mamba"], h, cfg, ctx), None
    if mixer == "cross":
        out = attn.cross_attn_apply(p["attn"], h, memory, cfg, ctx, gated=True)
        return out, cache
    raise ValueError(mixer)


def _apply_ffn(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx, kind: str | None):
    """Returns (out, aux)."""
    if "ffn" not in p or kind is None:
        return jnp.zeros_like(x), 0.0
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        return moe_mod.moe_apply(p["ffn"], h, cfg, ctx)
    return mlp_apply(p["ffn"], h, ctx), 0.0


def _block(
    p, x, cfg, ctx, *, mixer, ffn_kind, mode, cache=None, pos=None,
    positions=None, memory=None, window=None, causal=True, sp=False,
):
    mix_out, new_cache = _apply_mixer(
        p, x, cfg, ctx, mixer, mode, cache, pos, positions, memory, window,
        causal, sp,
    )
    x = x + mix_out
    ffn_out, aux = _apply_ffn(p, x, cfg, ctx, ffn_kind)
    x = x + ffn_out
    return x, new_cache, aux


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Full forward (train / prefill)
# ---------------------------------------------------------------------------


def _uniform_kind(cfg: ModelConfig) -> tuple[str, str | None]:
    if cfg.family == "ssm":
        return "mamba", None
    ffn = "moe" if cfg.n_experts > 0 else ("mlp" if cfg.d_ff else None)
    return "attn", ffn


def scan_maybe(scan_fn, init, xs, cfg: ModelConfig):
    """lax.scan, or an unrolled python loop when ``cfg.scan_layers`` is off
    (used by tests and by the dry-run's depth-extrapolation compiles —
    XLA's cost analysis counts a while body once, so per-layer costs are
    measured on small unrolled programs and extrapolated)."""
    if cfg.scan_layers:
        return jax.lax.scan(scan_fn, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        carry, y = scan_fn(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    stacked = (
        jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
        if ys and ys[0] is not None
        else None
    )
    return carry, stacked


def _scan_blocks(body, x, stacked_params, cfg: ModelConfig, caches=None):
    """Scan body over stacked layer params (+ caches); accumulates aux."""
    def scan_fn(carry, xs):
        x, aux = carry
        lp, cache = xs if caches is not None else (xs, None)
        x, new_cache, aux_l = body(x, lp, cache)
        return (x, aux + aux_l), new_cache

    xs = (stacked_params, caches) if caches is not None else stacked_params
    (x, aux), new_caches = scan_maybe(scan_fn, (x, 0.0), xs, cfg)
    return x, aux, new_caches


def encode(params, frames: jax.Array, cfg: ModelConfig, ctx: ShardingCtx):
    """Encoder stack over stub frame embeddings (encdec family)."""
    x = ctx.constrain(frames.astype(cfg.dtype), ("batch", "seq", "act_embed"))
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(x, lp, _):
        return _block(
            lp, x, cfg, ctx, mixer="attn", ffn_kind="mlp", mode="train",
            positions=positions, causal=False,
        )

    body = _maybe_remat(body, cfg)
    x, _, _ = _scan_blocks(body, x, params["enc_layers"], cfg)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    *,
    mode: str = "train",
) -> tuple[jax.Array, jax.Array, Any]:
    """Full-sequence forward.

    batch: tokens (B, S) [+ enc_frames (B,Se,D) | image_embeds (B,Si,D)].
    Returns (hidden (B,S,D), aux_loss, caches_or_None).
    """
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg, ctx)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    fam = cfg.family

    if fam in ("dense", "moe", "ssm"):
        mixer, ffn_kind = _uniform_kind(cfg)

        def body(x, lp, cache):
            return _block(
                lp, x, cfg, ctx, mixer=mixer, ffn_kind=ffn_kind, mode=mode,
                positions=positions, window=cfg.sliding_window, cache=cache,
            )

        body_r = _maybe_remat(body, cfg)
        x, aux, caches = _scan_blocks(body_r, x, params["layers"], cfg)

    elif fam in ("hybrid", "vlm"):
        period = _period_structure(cfg)
        memory = None
        if fam == "vlm":
            img = batch["image_embeds"].astype(cfg.dtype)
            # per-period cross K/V are projected inside the block from raw
            # image embeddings (each period has its own projections)
            memory_raw = ctx.constrain(img, ("batch", "kv_seq", "act_embed"))

        def body(x, period_params, cache):
            aux = 0.0
            new_caches = {}
            for i, (mixer, ffn_kind) in enumerate(period):
                p_i = period_params[f"pos{i}"]
                mem = None
                if mixer == "cross":
                    mem = attn.memory_kv(p_i["attn"], memory_raw, cfg, ctx)
                x, c_i, aux_i = _block(
                    p_i, x, cfg, ctx, mixer=mixer, ffn_kind=ffn_kind, mode=mode,
                    positions=positions, memory=mem, window=cfg.sliding_window,
                )
                if mode == "prefill":
                    new_caches[f"pos{i}"] = (
                        c_i if c_i is not None else {"unused": jnp.zeros((1,), cfg.dtype)}
                    )
                aux = aux + aux_i
            return x, new_caches if mode == "prefill" else None, aux

        body_r = _maybe_remat(body, cfg)
        x, aux, caches = _scan_blocks(body_r, x, params["periods"], cfg)

    elif fam == "encdec":
        enc = encode(params, batch["enc_frames"], cfg, ctx)

        def body(x, lp, cache):
            x, c, aux = _block(
                lp, x, cfg, ctx, mixer="attn", ffn_kind=None, mode=mode,
                positions=positions, cache=cache,
            )
            # cross attention sublayer
            mem = attn.memory_kv(lp["xattn"], enc, cfg, ctx)
            h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
            x = x + attn.cross_attn_apply(lp["xattn"], h, mem, cfg, ctx)
            ffn_out, aux2 = _apply_ffn(lp, x, cfg, ctx, "mlp")
            x = x + ffn_out
            return x, c, aux + aux2

        body_r = _maybe_remat(body, cfg)
        x, aux, caches = _scan_blocks(body_r, x, params["layers"], cfg)
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    return x, aux, caches


def lm_loss(
    params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx
) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy (+ MoE aux).  batch: tokens, labels."""
    x, aux, _ = forward(params, batch, cfg, ctx, mode="train")
    labels = batch["labels"]
    if cfg.logit_chunk:
        w = params["embed"].get("head")
        if w is None:
            w = params["embed"]["tok"].T
        ce = chunked_cross_entropy(x, w, labels, None, cfg.logit_chunk)
    else:
        logits = unembed(params["embed"], x, cfg, ctx)
        ce = cross_entropy(logits, labels)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode step
# ---------------------------------------------------------------------------


def _attn_cache(cfg: ModelConfig, batch: int, max_len: int):
    window = cfg.sliding_window
    s = min(max_len, window) if window else max_len
    shape = (batch, s, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def _ssm_cache(cfg: ModelConfig, batch: int):
    shapes = ssm_mod.ssm_cache_shape(cfg, batch)
    return {
        "conv_x": jnp.zeros(shapes["conv_x"], cfg.dtype),
        "conv_bc": jnp.zeros(shapes["conv_bc"], cfg.dtype),
        "state": jnp.zeros(shapes["state"], jnp.float32),
    }


def _stack_cache(cache: dict, n: int):
    return jax.tree.map(lambda a: jnp.tile(a, (n,) + (1,) * a.ndim), cache)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Zeroed serving cache for ``decode_step`` (static shapes)."""
    fam = cfg.family
    if fam in ("dense", "moe", "encdec"):
        return {"layers": _stack_cache(_attn_cache(cfg, batch, max_len), cfg.n_layers)}
    if fam == "ssm":
        return {"layers": _stack_cache(_ssm_cache(cfg, batch), cfg.n_layers)}
    if fam in ("hybrid", "vlm"):
        period = _period_structure(cfg)
        n_periods = cfg.n_layers // len(period)
        per = {}
        for i, (mixer, _) in enumerate(period):
            if mixer == "mamba":
                per[f"pos{i}"] = _ssm_cache(cfg, batch)
            elif mixer == "cross":  # static memory, no rolling state
                per[f"pos{i}"] = {"unused": jnp.zeros((1,), cfg.dtype)}
            else:
                per[f"pos{i}"] = _attn_cache(cfg, batch, max_len)
        return {"periods": _stack_cache(per, n_periods)}
    raise ValueError(fam)


_ATTN_CACHE_LOGICAL = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
}
_SSM_CACHE_LOGICAL = {
    "conv_x": ("layers", "batch", None, "conv_dim"),
    "conv_bc": ("layers", "batch", None, None),
    "state": ("layers", "batch", "ssm_heads", "ssm_state", None),
}


def cache_logical(cfg: ModelConfig) -> dict:
    """Logical sharding axes for the ``init_cache`` pytree."""
    fam = cfg.family
    if fam in ("dense", "moe", "encdec"):
        return {"layers": dict(_ATTN_CACHE_LOGICAL)}
    if fam == "ssm":
        return {"layers": dict(_SSM_CACHE_LOGICAL)}
    if fam in ("hybrid", "vlm"):
        period = _period_structure(cfg)
        per = {}
        for i, (mixer, _) in enumerate(period):
            if mixer == "mamba":
                per[f"pos{i}"] = dict(_SSM_CACHE_LOGICAL)
            elif mixer == "cross":
                per[f"pos{i}"] = {"unused": ("layers", None)}
            else:
                per[f"pos{i}"] = dict(_ATTN_CACHE_LOGICAL)
        return {"periods": per}
    raise ValueError(fam)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStruct cache (dry-run stand-in, no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def prime_memory(params, cfg: ModelConfig, ctx: ShardingCtx, batch: dict):
    """Precompute static cross-attention memory K/V for encdec/vlm decode."""
    if cfg.family == "encdec":
        enc = encode(params, batch["enc_frames"], cfg, ctx)

        def per_layer(lp):
            return attn.memory_kv(lp["xattn"], enc, cfg, ctx)

        return jax.vmap(per_layer)(params["layers"])
    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(cfg.dtype)

        def per_period(pp):
            return attn.memory_kv(pp["pos0"]["attn"], img, cfg, ctx)

        return jax.vmap(per_period)(params["periods"])
    return None


def decode_step(
    params: dict,
    token: jax.Array,  # (B, 1) int32
    cache: dict,
    pos: jax.Array,  # scalar int32
    cfg: ModelConfig,
    ctx: ShardingCtx,
    memory: Any = None,  # stacked cross K/V from prime_memory
    sp: bool = False,  # sequence-parallel KV cache (long-context decode)
) -> tuple[jax.Array, dict]:
    """One serving step: logits for the next token + updated cache."""
    x = embed_tokens(params["embed"], token, cfg, ctx)
    fam = cfg.family

    if fam in ("dense", "moe", "ssm"):
        mixer, ffn_kind = _uniform_kind(cfg)

        def body(x, lp, c):
            return _block(
                lp, x, cfg, ctx, mixer=mixer, ffn_kind=ffn_kind, mode="decode",
                cache=c, pos=pos, window=cfg.sliding_window, sp=sp,
            )

        x, _, new_caches = _scan_blocks(body, x, params["layers"], cfg,
                                        caches=cache["layers"])
        new_cache = {"layers": new_caches}

    elif fam in ("hybrid", "vlm"):
        period = _period_structure(cfg)

        def body(x, xs, _):
            if memory is not None:
                pp, pc, mem_p = xs
            else:
                pp, pc = xs
                mem_p = None
            new_pc = {}
            for i, (mixer, ffn_kind) in enumerate(period):
                p_i, c_i = pp[f"pos{i}"], pc[f"pos{i}"]
                if mixer == "cross":
                    h = rms_norm(x, p_i["ln1"], cfg.norm_eps)
                    out = attn.cross_attn_apply(p_i["attn"], h, mem_p, cfg, ctx, gated=True)
                    x = x + out
                    ffn_out, _ = _apply_ffn(p_i, x, cfg, ctx, ffn_kind)
                    x = x + ffn_out
                    new_pc[f"pos{i}"] = c_i
                else:
                    x, c_new, _ = _block(
                        p_i, x, cfg, ctx, mixer=mixer, ffn_kind=ffn_kind,
                        mode="decode", cache=c_i, pos=pos,
                        window=cfg.sliding_window, sp=sp,
                    )
                    new_pc[f"pos{i}"] = c_new
            return x, new_pc, 0.0

        def scan_fn(carry, xs):
            x, c, _ = body(carry, xs, None)
            return x, c

        xs = (params["periods"], cache["periods"])
        if memory is not None:
            xs = (*xs, memory)
        x, new_pcs = scan_maybe(scan_fn, x, xs, cfg)
        new_cache = {"periods": new_pcs}

    elif fam == "encdec":
        def scan_fn(x, xs):
            lp, c, mem = xs
            x, c_new, _ = _block(
                lp, x, cfg, ctx, mixer="attn", ffn_kind=None, mode="decode",
                cache=c, pos=pos,
            )
            h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
            x = x + attn.cross_attn_apply(lp["xattn"], h, mem, cfg, ctx)
            ffn_out, _ = _apply_ffn(lp, x, cfg, ctx, "mlp")
            x = x + ffn_out
            return x, c_new

        x, new_caches = scan_maybe(
            scan_fn, x, (params["layers"], cache["layers"], memory), cfg
        )
        new_cache = {"layers": new_caches}
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg, ctx)
    return logits, new_cache


def prefill(
    params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx, max_len: int
) -> tuple[jax.Array, dict]:
    """Run the prompt through the model and build the decode cache.

    Uniform across families: attention layers emit padded (ring-layout for
    SWA) KV buffers; Mamba layers emit their O(1) conv/SSD state.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x, _, caches = forward(params, batch, cfg, ctx, mode="prefill")
    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg, ctx)

    def pad_kv(kv):
        k, v = kv["k"], kv["v"]  # (L, B, S, Hkv, hd)
        window = cfg.sliding_window
        target = min(max_len, window) if window else max_len
        if s >= target:  # keep the trailing window, in ring layout
            k, v = k[:, :, s - target :], v[:, :, s - target :]
            if window:  # token t must sit at slot t % target
                shift = (s - target) % target
                k = jnp.roll(k, shift, axis=2)
                v = jnp.roll(v, shift, axis=2)
        else:
            pad = [(0, 0), (0, 0), (0, target - s), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": k.astype(cfg.dtype), "v": v.astype(cfg.dtype)}

    def fix(cache):
        if isinstance(cache, dict) and "k" in cache:
            return pad_kv(cache)
        if isinstance(cache, dict):
            return {key: fix(val) for key, val in cache.items()}
        return cache

    key = "periods" if cfg.family in ("hybrid", "vlm") else "layers"
    return logits, {key: fix(caches)}
