"""Attention modules: GQA self-attention (causal / SWA / bidir), cross
attention, decode against KV caches, and sequence-parallel long-context
decode (distributed flash-decode with log-sum-exp combination)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.parallel.sharding import shard_map  # version-compat shim
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import ref_attention
from repro.models.config import ModelConfig
from repro.models.init import ParamSpec
from repro.models.layers import rms_norm, rope
from repro.parallel.sharding import ShardingCtx

__all__ = [
    "attn_specs",
    "cross_attn_specs",
    "attn_apply",
    "attn_decode",
    "cross_attn_apply",
    "sp_decode_attention",
]


def attn_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    specs = {
        "wq": ParamSpec((d, hq, hd), ("embed", "q_heads", "head_dim"), dtype=cfg.pdtype),
        "wk": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=cfg.pdtype),
        "wv": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=cfg.pdtype),
        "wo": ParamSpec((hq, hd, d), ("q_heads", "head_dim", "embed"), dtype=cfg.pdtype),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), (None,), init="ones", dtype=jnp.float32)
        specs["k_norm"] = ParamSpec((hd,), (None,), init="ones", dtype=jnp.float32)
    if cross:
        specs["gate"] = ParamSpec((), (), init="zeros", dtype=jnp.float32)
    return specs


def cross_attn_specs(cfg: ModelConfig) -> dict:
    return attn_specs(cfg, cross=True)


def _project_q(p, x, cfg: ModelConfig, ctx, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
    return ctx.constrain(q, ("batch", "seq", "act_heads", "head_dim"))


def _project_kv(p, x, cfg: ModelConfig, ctx, positions):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:
        k = rope(k, positions, cfg.rope_theta)
    k = ctx.constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    v = ctx.constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
    return k, v


def _out_proj(p, o, ctx):
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return ctx.constrain(out, ("batch", "seq", "act_embed"))


def attn_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Full-sequence self attention (training / prefill)."""
    q = _project_q(p, x, cfg, ctx, positions)
    k, v = _project_kv(p, x, cfg, ctx, positions)
    o = flash_attention(
        q, k, v, causal=causal, window=window, impl=cfg.attn_impl,
        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
    )
    return _out_proj(p, o, ctx)


def cross_attn_apply(
    p: dict,
    x: jax.Array,
    memory_kv: tuple[jax.Array, jax.Array],
    cfg: ModelConfig,
    ctx: ShardingCtx,
    *,
    gated: bool = False,
) -> jax.Array:
    """Cross attention against precomputed memory K/V (no rope, no mask)."""
    q = _project_q(p, x, cfg, ctx, positions=None)
    k, v = memory_kv
    o = flash_attention(q, k, v, causal=False, impl=cfg.attn_impl,
                        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k)
    out = _out_proj(p, o, ctx)
    if gated:  # llama-3.2-vision tanh gate, initialized at 0
        out = jnp.tanh(p["gate"]).astype(out.dtype) * out
    return out


def memory_kv(p: dict, memory: jax.Array, cfg: ModelConfig, ctx: ShardingCtx):
    """Precompute cross-attention K/V once per sequence (serving + training)."""
    return _project_kv(p, memory, cfg, ctx, positions=None)


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------


def attn_decode(
    p: dict,
    x: jax.Array,  # (B, 1, D)
    k_cache: jax.Array,  # (B, S_max, Hkv, hd)
    v_cache: jax.Array,
    pos: jax.Array,  # scalar int32: index of the new token
    cfg: ModelConfig,
    ctx: ShardingCtx,
    *,
    ring: bool = False,
    sp: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention; returns (out, new_k_cache, new_v_cache).

    ``ring=True`` treats the cache as a sliding-window ring buffer of
    width S_max (Mixtral SWA long-decode).  ``sp=True`` uses the
    sequence-parallel distributed decode path (cache sharded over "data").
    """
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q = _project_q(p, x, cfg, ctx, positions)
    k_new, v_new = _project_kv(p, x, cfg, ctx, positions)

    s_max = k_cache.shape[1]
    slot = pos % s_max if ring else jnp.minimum(pos, s_max - 1)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    kv_len = jnp.minimum(pos + 1, s_max)

    if sp and ctx.mesh is not None and "data" in ctx.mesh.axis_names:
        o = sp_decode_attention(q, k_cache, v_cache, kv_len, ctx)
    else:
        # ring buffers hold an arbitrary rotation of the window; positions
        # within the window are order-invariant for softmax attention.
        o = ref_attention(
            q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
            causal=False, kv_len=kv_len,
        )
    return _out_proj(p, o, ctx), k_cache, v_cache


def sp_decode_attention(
    q: jax.Array,        # (B, 1, Hq, hd) replicated over "data"
    k_cache: jax.Array,  # (B, S, Hkv, hd) sharded over "data" on S
    v_cache: jax.Array,
    kv_len: jax.Array,
    ctx: ShardingCtx,
) -> jax.Array:
    """Distributed flash-decode: each data shard attends over its KV slice,
    then partial outputs are combined with log-sum-exp weights via psum.

    This is the long-context (batch=1) serving path: the 500k-token KV
    cache is sharded over the 16-way "data" axis, so per-chip cache bytes
    drop 16× and the attention reduction parallelizes."""
    mesh = ctx.mesh
    dspec = ctx.rules.resolve(("batch", "kv_seq", "kv_heads", "head_dim"), mesh)
    qspec = ctx.rules.resolve(("batch", None, "act_heads", "head_dim"), mesh)
    hq_global = q.shape[2]
    hkv_global = k_cache.shape[2]
    group = hq_global // hkv_global

    def local(q, k, v, kv_len):
        # q: heads sharded over "model"; k/v: seq sharded over "data",
        # kv heads replicated.  Local q heads are a contiguous global
        # slice, so their GQA kv-head mapping uses GLOBAL head indices.
        b, s_loc, _, hd = k.shape
        hq_loc = q.shape[2]
        head_off = jax.lax.axis_index("model") * hq_loc
        kvh = (head_off + jnp.arange(hq_loc)) // group  # (hq_loc,)
        k_sel = jnp.take(k, kvh, axis=2)  # (b, s_loc, hq_loc, hd)
        v_sel = jnp.take(v, kvh, axis=2)
        seq_off = jax.lax.axis_index("data") * s_loc

        qf = q.astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf * hd**-0.5, k_sel.astype(jnp.float32))
        valid = (jnp.arange(s_loc) + seq_off < kv_len)[None, None, None, :]
        s = jnp.where(valid, s, -jnp.inf)
        m_loc = jnp.max(s, axis=-1)  # (b, hq_loc, 1)
        m_glob = jax.lax.pmax(jnp.where(jnp.isfinite(m_loc), m_loc, -1e30), "data")
        p = jnp.exp(s - m_glob[..., None])
        p = jnp.where(valid, p, 0.0)
        num = jnp.einsum("bhqk,bkhd->bqhd", p, v_sel.astype(jnp.float32))
        den = jnp.sum(p, axis=-1)  # (b, hq_loc, 1)
        num = jax.lax.psum(num, "data")
        den = jax.lax.psum(den, "data")
        o = num / jnp.maximum(den, 1e-30).transpose(0, 2, 1)[..., None]
        return o.astype(q.dtype)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(qspec, dspec, dspec, P()),
        out_specs=qspec,
        check_vma=False,
    )(q, k_cache, v_cache, kv_len)
