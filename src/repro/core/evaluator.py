"""Exact and Monte-Carlo evaluation of expected sojourn time of successful jobs.

The paper (Section IV-A1) evaluates a schedule *exactly* by enumerating all
combinations of per-job outcomes (which checkpoint each job stops at),
weighting each combination by its probability.  We reproduce that scheme,
vectorized with JAX:

* :func:`expected_sojourn_static` — a batch of static non-preemptive orders
  (Theorem III.1 justifies restricting to these for RANK/OPTIMAL/RANDOM)
  evaluated against all outcome combinations at once.
* :func:`expected_sojourn_dynamic` — stage-level policies (SR / SERPT /
  conditional-RANK) simulated in lockstep across all outcome combinations
  with a ``lax.fori_loop`` (single-server, simultaneous arrivals).
* :func:`optimal_order` — exhaustive search over permutations (N <= 9).
* Monte-Carlo fallbacks for workloads whose combination count explodes.

Conventions: a combination with zero successful jobs contributes 0 (the
paper's Eqs. (7)-(9) sum from l >= 1 successes).
"""

from __future__ import annotations

import functools
import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies
from repro.core.jobs import Workload, pad_workload

__all__ = [
    "enumerate_outcomes",
    "sample_outcomes",
    "expected_sojourn_static",
    "expected_sojourn_dynamic",
    "optimal_order",
    "evaluate",
]

#: Above this many outcome combinations, fall back to Monte Carlo.
MAX_EXACT_COMBOS = 1 << 21


# ---------------------------------------------------------------------------
# Outcome enumeration
# ---------------------------------------------------------------------------


def enumerate_outcomes(jobs: Workload) -> tuple[np.ndarray, np.ndarray]:
    """All outcome combinations.

    Returns:
      outcomes: (K, N) int32 — for each combination, the stage index at
        which each job stops (M_i - 1 == success).
      weights:  (K,) float64 — probability of each combination.
    """
    _, probs, num_stages = pad_workload(jobs)
    k_total = int(np.prod(num_stages))
    if k_total > MAX_EXACT_COMBOS:
        raise ValueError(
            f"{k_total} combinations exceed MAX_EXACT_COMBOS; use sample_outcomes"
        )
    grids = np.meshgrid(*[np.arange(m) for m in num_stages], indexing="ij")
    outcomes = np.stack([g.reshape(-1) for g in grids], axis=1).astype(np.int32)
    weights = np.ones((k_total,), dtype=np.float64)
    for i in range(len(jobs)):
        weights *= probs[i, outcomes[:, i]]
    return outcomes, weights


def sample_outcomes(
    jobs: Workload, n_samples: int, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray]:
    """Monte-Carlo outcome sampling; weights are uniform 1/S."""
    _, probs, num_stages = pad_workload(jobs)
    n = len(jobs)
    outcomes = np.empty((n_samples, n), dtype=np.int32)
    for i in range(n):
        outcomes[:, i] = rng.choice(
            num_stages[i], size=n_samples, p=probs[i, : num_stages[i]]
        )
    weights = np.full((n_samples,), 1.0 / n_samples)
    return outcomes, weights


def _realized_arrays(jobs: Workload, outcomes: np.ndarray):
    """Per-combination realized durations and success masks."""
    sizes, _, num_stages = pad_workload(jobs)
    durations = sizes[np.arange(len(jobs)), outcomes]  # (K, N) fancy gather
    success = outcomes == (num_stages[None, :] - 1)
    return durations, success


# ---------------------------------------------------------------------------
# Static non-preemptive orders (JAX, batched over orders)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("also_all_jobs",))
def _static_batch(durations, success, weights, orders, also_all_jobs=False):
    """E[sojourn of successful jobs] for each order in a batch.

    durations: (K, N)  realized total service per job per combination
    success:   (K, N)  bool
    weights:   (K,)
    orders:    (P, N)  job permutations
    """

    def one_order(order):
        d = jnp.take(durations, order, axis=1)  # (K, N)
        s = jnp.take(success, order, axis=1)
        t = jnp.cumsum(d, axis=1)  # completion times
        cnt = jnp.sum(s, axis=1)
        tot = jnp.sum(t * s, axis=1)
        mean_succ = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), 0.0)
        e_succ = jnp.dot(weights, mean_succ)
        if also_all_jobs:
            e_all = jnp.dot(weights, jnp.mean(t, axis=1))
            return e_succ, e_all
        return e_succ

    return jax.vmap(one_order)(orders)


def expected_sojourn_static(
    jobs: Workload,
    orders: np.ndarray,
    outcomes: np.ndarray | None = None,
    weights: np.ndarray | None = None,
    batch: int = 4096,
    also_all_jobs: bool = False,
):
    """Exact expected sojourn of successful jobs for static order(s).

    ``orders`` may be (N,) for a single order or (P, N) for a batch.
    """
    orders = np.asarray(orders, dtype=np.int32)
    single = orders.ndim == 1
    if single:
        orders = orders[None]
    if outcomes is None:
        outcomes, weights = enumerate_outcomes(jobs)
    durations, success = _realized_arrays(jobs, outcomes)
    dj = jnp.asarray(durations)
    sj = jnp.asarray(success)
    wj = jnp.asarray(weights)
    outs = []
    for lo in range(0, orders.shape[0], batch):
        chunk = jnp.asarray(orders[lo : lo + batch])
        outs.append(_static_batch(dj, sj, wj, chunk, also_all_jobs=also_all_jobs))
    if also_all_jobs:
        e_succ = np.concatenate([np.asarray(o[0]) for o in outs])
        e_all = np.concatenate([np.asarray(o[1]) for o in outs])
        return (e_succ[0], e_all[0]) if single else (e_succ, e_all)
    res = np.concatenate([np.asarray(o) for o in outs])
    return float(res[0]) if single else res


# ---------------------------------------------------------------------------
# Dynamic stage-level policies (JAX lockstep simulation over combinations)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("total_stages",))
def _dynamic_batch(idx_table, stage_durs, outcomes, success, weights, total_stages):
    """Simulate a stage-level index policy for every outcome combination.

    idx_table:  (N, M)   priority after surviving s checkpoints (+inf pad)
    stage_durs: (N, M)   duration of executing checkpoint segment s
    outcomes:   (K, N)   stop-stage per combination
    success:    (K, N)   bool
    """
    k, n = outcomes.shape

    def sim(outcome, succ):
        def body(_, state):
            stage, clock, tdone, done = state
            alive = ~done
            idx = jnp.where(
                alive, idx_table[jnp.arange(n), jnp.minimum(stage, idx_table.shape[1] - 1)],
                jnp.inf,
            )
            any_alive = jnp.any(alive)
            j = jnp.argmin(idx)
            dur = jnp.where(any_alive, stage_durs[j, stage[j]], 0.0)
            clock = clock + dur
            fin = stage[j] >= outcome[j]
            stage = stage.at[j].add(jnp.where(any_alive, 1, 0))
            newly_done = any_alive & fin
            tdone = jnp.where(newly_done, tdone.at[j].set(clock), tdone)
            done = done.at[j].set(done[j] | newly_done)
            return stage, clock, tdone, done

        stage0 = jnp.zeros((n,), dtype=jnp.int32)
        tdone0 = jnp.zeros((n,))
        done0 = jnp.zeros((n,), dtype=bool)
        _, _, tdone, _ = jax.lax.fori_loop(
            0, total_stages, body, (stage0, 0.0, tdone0, done0)
        )
        cnt = jnp.sum(succ)
        tot = jnp.sum(tdone * succ)
        return jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), 0.0)

    means = jax.vmap(sim)(outcomes, success)
    return jnp.dot(weights, means)


def expected_sojourn_dynamic(
    jobs: Workload,
    policy: str,
    outcomes: np.ndarray | None = None,
    weights: np.ndarray | None = None,
) -> float:
    """Exact expected sojourn of successful jobs for a stage-level policy."""
    if outcomes is None:
        outcomes, weights = enumerate_outcomes(jobs)
    sizes, _, num_stages = pad_workload(jobs)
    idx_table = policies.index_table(jobs, policy)
    stage_durs = np.diff(sizes, axis=1, prepend=0.0)
    _, success = _realized_arrays(jobs, outcomes)
    total_stages = int(num_stages.sum())
    val = _dynamic_batch(
        jnp.asarray(idx_table),
        jnp.asarray(stage_durs),
        jnp.asarray(outcomes),
        jnp.asarray(success),
        jnp.asarray(weights),
        total_stages,
    )
    return float(val)


# ---------------------------------------------------------------------------
# Exhaustive OPTIMAL (N <= 9) and the public entry point
# ---------------------------------------------------------------------------


def optimal_order(jobs: Workload, max_n: int = 9) -> tuple[np.ndarray, float]:
    """Exhaustive search over all N! non-preemptive orders (Thm III.1)."""
    n = len(jobs)
    if n > max_n:
        raise ValueError(f"exhaustive search with N={n} > {max_n} is too expensive")
    orders = np.array(list(itertools.permutations(range(n))), dtype=np.int32)
    vals = expected_sojourn_static(jobs, orders)
    best = int(np.argmin(vals))
    return orders[best], float(vals[best])


def evaluate(
    jobs: Workload,
    policy: str,
    rng: np.random.Generator | None = None,
    outcomes: np.ndarray | None = None,
    weights: np.ndarray | None = None,
) -> float:
    """Expected sojourn time of successful jobs under ``policy``.

    Policies: 'rank' | 'serpt' | 'sr' | 'random' | 'optimal'.
    RANK and RANDOM are static orders (Theorem III.1); SERPT and SR are
    stage-level index policies as in the paper's Section III-A examples.
    """
    if policy == "rank":
        return expected_sojourn_static(jobs, policies.rank_order(jobs), outcomes, weights)
    if policy == "random":
        if rng is None:
            raise ValueError("random policy needs an rng")
        return expected_sojourn_static(
            jobs, policies.random_order(jobs, rng), outcomes, weights
        )
    if policy == "optimal":
        _, val = optimal_order(jobs)
        return val
    if policy in ("serpt", "sr"):
        return expected_sojourn_dynamic(jobs, policy, outcomes, weights)
    raise ValueError(f"unknown policy {policy!r}")


def exact_combination_count(jobs: Workload) -> int:
    _, _, num_stages = pad_workload(jobs)
    return int(np.prod(num_stages))


def evaluate_many(
    jobs: Workload,
    algs: tuple[str, ...],
    rng: np.random.Generator,
    mc_samples: int = 4096,
) -> dict[str, float]:
    """Evaluate several policies on one job group, sharing outcome tables."""
    if exact_combination_count(jobs) <= MAX_EXACT_COMBOS:
        outcomes, weights = enumerate_outcomes(jobs)
    else:
        outcomes, weights = sample_outcomes(jobs, mc_samples, rng)
    return {
        alg: evaluate(jobs, alg, rng=rng, outcomes=outcomes, weights=weights)
        for alg in algs
    }
