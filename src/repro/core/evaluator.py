"""Exact and Monte-Carlo evaluation of expected sojourn time of successful jobs.

The paper (Section IV-A1) evaluates a schedule *exactly* by enumerating all
combinations of per-job outcomes (which checkpoint each job stops at),
weighting each combination by its probability.  We reproduce that scheme,
fused and vectorized:

* :func:`expected_sojourn_static` — a batch of static non-preemptive orders
  (Theorem III.1 justifies restricting to these for RANK/OPTIMAL/RANDOM)
  evaluated by the fused :mod:`repro.kernels.sojourn_eval` op, which
  decodes outcome combinations on the fly inside the kernel instead of
  materializing the ``(K, N)`` outcome matrix host-side.  Exact
  evaluation scales to ``MAX_EXACT_COMBOS = 2**26`` combinations in
  bounded memory; explicit outcome tables (Monte-Carlo samples or a
  shared exact table) ride the same op's streaming path.
* :func:`expected_sojourn_dynamic` — stage-level policies (SR / SERPT /
  conditional-RANK) evaluated by the fused
  :mod:`repro.kernels.sojourn_eval.dynamic` op, which decodes outcome
  combinations on the fly and runs the single-server stage-boundary
  preemption simulation *inside* each tile, so exact dynamic evaluation
  also scales to ``MAX_EXACT_COMBOS`` with no outcome table.  Explicit
  outcome tables (Monte-Carlo samples) ride the legacy
  :func:`_dynamic_batch` lockstep simulation, which is retained as the
  ``<= MAX_MATERIALIZED_COMBOS`` reference tier for differential tests.
* :func:`optimal_order` — exhaustive search over permutations (N <= 9).
* Beyond ``MAX_EXACT_COMBOS``, both ops switch to *streaming* Monte
  Carlo via ``samples=(seed, n_samples)``: outcomes are generated
  inside the fused kernels from a counter-based Threefry stream keyed
  by ``(seed, sample, job)``, so no (S, N) sample table is ever
  materialized and all policies under one seed share identical outcome
  streams (common random numbers; see ``docs/streaming_mc.md``).
  :func:`sample_outcomes` + explicit tables remain as the legacy
  materialized tier.

Static-order evaluation runs under ``jax.experimental.enable_x64`` so the
fused op accumulates in float64 (<=1e-9 agreement with the seed path).
Enumeration metadata (mixed-radix strides, combination counts) and padded
workload arrays are cached per workload via
:func:`repro.core.policies.workload_cached`, so the DES and cluster
manager reuse them across policy x trial sweeps.

Conventions: a combination with zero successful jobs contributes 0 (the
paper's Eqs. (7)-(9) sum from l >= 1 successes).
"""

from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies
from repro.core.jobs import Workload
from repro.kernels.sojourn_eval import rng as kernel_rng
from repro.kernels.sojourn_eval import sojourn_eval, sojourn_eval_dynamic
from repro.kernels.sojourn_eval.ref import mixed_radix_strides

__all__ = [
    "enumerate_outcomes",
    "sample_outcomes",
    "expected_sojourn_static",
    "expected_sojourn_dynamic",
    "optimal_order",
    "evaluate",
    "evaluate_many",
]

#: Above this many outcome combinations, exact *static-order* evaluation
#: (which streams combinations through the fused kernel without ever
#: materializing them) falls back to Monte Carlo.
MAX_EXACT_COMBOS = 1 << 26

#: Above this many combinations, a (K, N) outcome table is too large to
#: materialize (dynamic-policy lockstep simulation and shared exact tables).
MAX_MATERIALIZED_COMBOS = 1 << 21


def _x64():
    """Static-order evaluation runs in float64 end to end."""
    return jax.experimental.enable_x64(True)


# ---------------------------------------------------------------------------
# Outcome enumeration
# ---------------------------------------------------------------------------


def _enum_meta(jobs: Workload) -> tuple[int, np.ndarray, np.ndarray]:
    """Cached (K, strides, num_stages) mixed-radix enumeration metadata."""

    def compute():
        _, _, num_stages = policies.padded_arrays(jobs)
        k_total = int(np.prod(num_stages, dtype=np.int64))
        return k_total, mixed_radix_strides(num_stages), num_stages

    return policies.workload_cached("enum_meta", jobs, compute)


def enumerate_outcomes(jobs: Workload) -> tuple[np.ndarray, np.ndarray]:
    """All outcome combinations, materialized.

    Returns:
      outcomes: (K, N) int32 — for each combination, the stage index at
        which each job stops (M_i - 1 == success).
      weights:  (K,) float64 — probability of each combination.

    Only valid up to ``MAX_MATERIALIZED_COMBOS``; the fused evaluator
    handles larger exact enumerations without materialization.
    """
    _, probs, _ = policies.padded_arrays(jobs)
    k_total, strides, num_stages = _enum_meta(jobs)
    if k_total > MAX_MATERIALIZED_COMBOS:
        raise ValueError(
            f"{k_total} combinations exceed MAX_MATERIALIZED_COMBOS; use "
            "sample_outcomes, or expected_sojourn_static(outcomes=None) "
            "which enumerates inside the fused kernel"
        )
    # Single vectorized mixed-radix decode + gathered weight product (the
    # seed looped over jobs for both the meshgrid and the product).
    k = np.arange(k_total, dtype=np.int64)
    outcomes = ((k[:, None] // strides[None, :]) % num_stages[None, :]).astype(
        np.int32
    )
    weights = np.prod(
        probs[np.arange(len(jobs))[None, :], outcomes], axis=1, dtype=np.float64
    )
    return outcomes, weights


def sample_outcomes(
    jobs: Workload, n_samples: int, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray]:
    """Monte-Carlo outcome sampling; weights are uniform 1/S.

    Vectorized inverse-CDF sampling over the whole (S, N) matrix in one
    shot (the seed drew per-job ``rng.choice`` columns in a Python loop).
    """
    _, probs, num_stages = policies.padded_arrays(jobs)
    cdf = np.cumsum(probs, axis=1)  # (N, M); padded stages add 0 mass
    u = rng.random((n_samples, len(jobs)))
    outcomes = np.sum(u[:, :, None] >= cdf[None, :, :], axis=2)
    outcomes = np.minimum(outcomes, num_stages[None, :] - 1).astype(np.int32)
    weights = np.full((n_samples,), 1.0 / n_samples)
    return outcomes, weights


def _realized_arrays(jobs: Workload, outcomes: np.ndarray):
    """Per-combination realized durations and success masks."""
    sizes, _, num_stages = policies.padded_arrays(jobs)
    durations = sizes[np.arange(len(jobs)), outcomes]  # (K, N) fancy gather
    success = outcomes == (num_stages[None, :] - 1)
    return durations, success


# ---------------------------------------------------------------------------
# Static non-preemptive orders (fused sojourn_eval op)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("also_all_jobs",))
def _static_batch(durations, success, weights, orders, also_all_jobs=False):
    """Seed reference path: E[sojourn of successful jobs] per order.

    Retained as the parity oracle for the fused op (tests and the
    ``table_eval_perf`` benchmark); production calls go through
    :func:`repro.kernels.sojourn_eval.sojourn_eval`.

    durations: (K, N)  realized total service per job per combination
    success:   (K, N)  bool
    weights:   (K,)
    orders:    (P, N)  job permutations
    """

    def one_order(order):
        d = jnp.take(durations, order, axis=1)  # (K, N)
        s = jnp.take(success, order, axis=1)
        t = jnp.cumsum(d, axis=1)  # completion times
        cnt = jnp.sum(s, axis=1)
        tot = jnp.sum(t * s, axis=1)
        mean_succ = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), 0.0)
        e_succ = jnp.dot(weights, mean_succ)
        if also_all_jobs:
            e_all = jnp.dot(weights, jnp.mean(t, axis=1))
            return e_succ, e_all
        return e_succ

    return jax.vmap(one_order)(orders)


def expected_sojourn_static(
    jobs: Workload,
    orders: np.ndarray,
    outcomes: np.ndarray | None = None,
    weights: np.ndarray | None = None,
    also_all_jobs: bool = False,
    impl: str = "auto",
    samples: tuple[int, int] | None = None,
):
    """Expected sojourn of successful jobs for static order(s), fused.

    ``orders`` may be (N,) for a single order or (P, N) for a batch.
    With ``outcomes=None`` the evaluation is exact: all ``prod(M_i)``
    combinations are enumerated *inside* the fused kernel (up to
    ``MAX_EXACT_COMBOS``, never materializing a (K, N) array).  Passing
    explicit ``outcomes``/``weights`` (Monte-Carlo samples or a shared
    exact table) streams them through the same op.  Passing
    ``samples=(seed, n_samples)`` instead runs *streaming* Monte Carlo:
    outcomes are generated inside the op from the counter-based RNG
    stream, so no (S, N) sample table is ever materialized and every
    order/policy under one seed sees identical outcomes.
    """
    orders = np.asarray(orders, dtype=np.int32)
    single = orders.ndim == 1
    if single:
        orders = orders[None]
    sizes, probs, num_stages = policies.padded_arrays(jobs)
    if outcomes is None and samples is None:
        k_total, _, _ = _enum_meta(jobs)
        if k_total > MAX_EXACT_COMBOS:
            raise ValueError(
                f"{k_total} combinations exceed MAX_EXACT_COMBOS; use "
                "samples=(seed, n_samples) or sample_outcomes"
            )
    with _x64():
        e_succ, e_all = sojourn_eval(
            sizes,
            probs,
            num_stages,
            orders,
            outcomes=outcomes,
            weights=weights,
            samples=samples,
            impl=impl,
        )
    if also_all_jobs:
        return (e_succ[0], e_all[0]) if single else (e_succ, e_all)
    return float(e_succ[0]) if single else e_succ


# ---------------------------------------------------------------------------
# Dynamic stage-level policies (JAX lockstep simulation over combinations)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("total_stages",))
def _dynamic_batch(idx_table, stage_durs, outcomes, success, weights, total_stages):
    """Simulate a stage-level index policy for every outcome combination.

    Retained as the ``<= MAX_MATERIALIZED_COMBOS`` reference tier (and the
    Monte-Carlo path) for the fused streaming op in
    :mod:`repro.kernels.sojourn_eval.dynamic`; the differential suite
    checks the two against each other and the dense oracle.

    idx_table:  (N, M)   priority after surviving s checkpoints (+inf pad)
    stage_durs: (N, M)   duration of executing checkpoint segment s
    outcomes:   (K, N)   stop-stage per combination
    success:    (K, N)   bool
    """
    k, n = outcomes.shape

    def sim(outcome, succ):
        def body(_, state):
            stage, clock, tdone, done = state
            alive = ~done
            idx = jnp.where(
                alive, idx_table[jnp.arange(n), jnp.minimum(stage, idx_table.shape[1] - 1)],
                jnp.inf,
            )
            any_alive = jnp.any(alive)
            j = jnp.argmin(idx)
            dur = jnp.where(any_alive, stage_durs[j, stage[j]], 0.0)
            clock = clock + dur
            fin = stage[j] >= outcome[j]
            stage = stage.at[j].add(jnp.where(any_alive, 1, 0))
            newly_done = any_alive & fin
            tdone = jnp.where(newly_done, tdone.at[j].set(clock), tdone)
            done = done.at[j].set(done[j] | newly_done)
            return stage, clock, tdone, done

        stage0 = jnp.zeros((n,), dtype=jnp.int32)
        tdone0 = jnp.zeros((n,))
        done0 = jnp.zeros((n,), dtype=bool)
        _, _, tdone, _ = jax.lax.fori_loop(
            0, total_stages, body, (stage0, 0.0, tdone0, done0)
        )
        cnt = jnp.sum(succ)
        tot = jnp.sum(tdone * succ)
        return jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), 0.0)

    means = jax.vmap(sim)(outcomes, success)
    return jnp.dot(weights, means)


def expected_sojourn_dynamic(
    jobs: Workload,
    policy: str,
    outcomes: np.ndarray | None = None,
    weights: np.ndarray | None = None,
    impl: str = "auto",
    samples: tuple[int, int] | None = None,
    n_servers: int = 1,
) -> float:
    """Exact expected sojourn of successful jobs for a stage-level policy.

    With ``outcomes=None`` the evaluation is exact: all ``prod(M_i)``
    combinations are decoded and *simulated* inside the fused dynamic
    kernel (up to ``MAX_EXACT_COMBOS``, no (K, N) outcome table).
    Passing ``samples=(seed, n_samples)`` runs streaming Monte Carlo
    through the same fused op — outcomes are generated in-tile from the
    counter-based RNG stream shared with the static op, so no (S, N)
    table exists at any sample count.  ``n_servers=W`` evaluates the
    paper's online multi-server setting exactly (or by streamed MC);
    both fused entry modes support it.  Passing explicit
    ``outcomes``/``weights`` (a materialized table) runs the legacy
    lockstep simulation, retained as the single-server reference tier.
    """
    _, probs, num_stages = policies.padded_arrays(jobs)
    idx_table = policies.index_table(jobs, policy)
    stage_durs = policies.stage_durations(jobs)
    if samples is not None:
        with _x64():
            e_succ, _ = sojourn_eval_dynamic(
                probs, stage_durs, num_stages, idx_table,
                samples=samples, n_servers=n_servers, impl=impl,
            )
        return float(e_succ[0])
    if outcomes is None:
        k_total, _, _ = _enum_meta(jobs)
        if k_total > MAX_EXACT_COMBOS:
            raise ValueError(
                f"{k_total} combinations exceed MAX_EXACT_COMBOS; use "
                "samples=(seed, n_samples) or sample_outcomes"
            )
        with _x64():
            e_succ, _ = sojourn_eval_dynamic(
                probs, stage_durs, num_stages, idx_table,
                n_servers=n_servers, impl=impl,
            )
        return float(e_succ[0])
    if n_servers != 1:
        raise ValueError(
            "the materialized outcomes/weights tier is single-server; "
            "use the fused path (outcomes=None or samples=) for n_servers > 1"
        )
    _, success = _realized_arrays(jobs, outcomes)
    total_stages = int(num_stages.sum())
    with _x64():
        val = _dynamic_batch(
            jnp.asarray(np.float64(idx_table)),
            jnp.asarray(np.float64(stage_durs)),
            jnp.asarray(outcomes),
            jnp.asarray(success),
            jnp.asarray(np.float64(weights)),
            total_stages,
        )
        return float(val)


# ---------------------------------------------------------------------------
# Exhaustive OPTIMAL (N <= 9) and the public entry point
# ---------------------------------------------------------------------------


def optimal_order(jobs: Workload, max_n: int = 9) -> tuple[np.ndarray, float]:
    """Exhaustive search over all N! non-preemptive orders (Thm III.1)."""
    n = len(jobs)
    if n > max_n:
        raise ValueError(f"exhaustive search with N={n} > {max_n} is too expensive")
    orders = np.array(list(itertools.permutations(range(n))), dtype=np.int32)
    vals = expected_sojourn_static(jobs, orders)
    best = int(np.argmin(vals))
    return orders[best], float(vals[best])


def evaluate(
    jobs: Workload,
    policy: str,
    rng: np.random.Generator | None = None,
    outcomes: np.ndarray | None = None,
    weights: np.ndarray | None = None,
    samples: tuple[int, int] | None = None,
) -> float:
    """Expected sojourn time of successful jobs under ``policy``.

    Policies: 'rank' | 'serpt' | 'sr' | 'random' | 'optimal'.
    RANK and RANDOM are static orders (Theorem III.1); SERPT and SR are
    stage-level index policies as in the paper's Section III-A examples.
    ``samples=(seed, n_samples)`` runs streaming Monte Carlo with a
    shared counter stream (common random numbers across policies).
    """
    if policy == "rank":
        return expected_sojourn_static(
            jobs, policies.rank_order(jobs), outcomes, weights, samples=samples
        )
    if policy == "random":
        if rng is None:
            raise ValueError("random policy needs an rng")
        return expected_sojourn_static(
            jobs, policies.random_order(jobs, rng), outcomes, weights,
            samples=samples,
        )
    if policy == "optimal":
        _, val = optimal_order(jobs)
        return val
    if policy in ("serpt", "sr"):
        return expected_sojourn_dynamic(
            jobs, policy, outcomes, weights, samples=samples
        )
    raise ValueError(f"unknown policy {policy!r}")


def exact_combination_count(jobs: Workload) -> int:
    return _enum_meta(jobs)[0]


def evaluate_many(
    jobs: Workload,
    algs: tuple[str, ...],
    rng: np.random.Generator,
    mc_samples: int = 4096,
) -> dict[str, float]:
    """Evaluate several policies on one job group, sharing random numbers.

    Two regimes by combination count K (static *and* dynamic policies
    stream through fused kernels, so no policy ever needs a materialized
    (K, N) outcome table):
      * K <= MAX_EXACT_COMBOS: everything is exact — static orders via
        :func:`repro.kernels.sojourn_eval.sojourn_eval`, SR/SERPT via
        :func:`repro.kernels.sojourn_eval.sojourn_eval_dynamic`.
      * otherwise: *streaming* Monte Carlo with one seed drawn from
        ``rng`` and shared by every policy (common random numbers) — the
        counter-based stream is keyed by original job id, so all
        policies see the identical outcome sequence without any (S, N)
        sample table ever existing.
    """
    k_total = exact_combination_count(jobs)
    if k_total <= MAX_EXACT_COMBOS:
        return {alg: evaluate(jobs, alg, rng=rng) for alg in algs}
    seed = int(rng.integers(0, kernel_rng.MAX_SEED))
    return {
        alg: evaluate(jobs, alg, rng=rng, samples=(seed, mc_samples))
        for alg in algs
    }
