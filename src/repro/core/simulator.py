"""Discrete-event simulator for the online multi-server setting (paper §V-VI).

Implements the paper's online approach: W homogeneous servers; when a new
job arrives it is served immediately if a server is free, otherwise queued.
When a server completes a *stage* of a job, it serves the minimum-index job
among {ready queue} ∪ {the job it just served} — i.e. stage-boundary
preemption driven by a policy index table (rank / SERPT / SR / FIFO).

This is host-side control logic (microsecond-scale events); it drives both
the paper's trace study and the cluster manager in :mod:`repro.cluster`.
The index is *conditional on progress*: a partially-served job competes
with its up-to-date conditional index (see
:func:`repro.core.policies.rank_index_table`).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np

from repro.core import policies
from repro.core.jobs import Workload

__all__ = ["SimResult", "ReadyQueue", "simulate"]


@dataclasses.dataclass
class SimResult:
    mean_sojourn_successful: float
    mean_sojourn_all: float
    n_success: int
    n_jobs: int
    makespan: float
    policy: str
    n_servers: int

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


class ReadyQueue:
    """Priority queue of waiting jobs keyed by policy index (min first).

    Queued jobs never change stage, so indices never go stale; O(log N)
    push/pop as noted in the paper's Section V.
    """

    def __init__(self):
        self._heap: list[tuple[float, int, int]] = []
        self._seq = itertools.count()

    def push(self, index: float, job: int) -> None:
        heapq.heappush(self._heap, (index, next(self._seq), job))

    def pop(self) -> int:
        return heapq.heappop(self._heap)[2]

    def peek_index(self) -> float:
        return self._heap[0][0] if self._heap else np.inf

    def __len__(self) -> int:
        return len(self._heap)


def _realize_outcomes(jobs: Workload, rng: np.random.Generator | None) -> np.ndarray:
    out = np.empty(len(jobs), dtype=np.int64)
    for i, j in enumerate(jobs):
        if j.outcome_stage >= 0:
            out[i] = j.outcome_stage
        else:
            if rng is None:
                raise ValueError("jobs without fixed outcomes need an rng")
            out[i] = rng.choice(j.num_stages, p=j.probs)
    return out


def simulate(
    jobs: Workload,
    n_servers: int,
    policy: str = "rank",
    idx_table: np.ndarray | None = None,
    rng: np.random.Generator | None = None,
    stage_overhead: float = 0.0,
) -> SimResult:
    """Run the online scheduler over a trace.

    Args:
      jobs: workload; each job's ``arrival`` is honored and its realized
        ``outcome_stage`` is used if set (trace-driven), else sampled.
      n_servers: W homogeneous servers.
      policy: 'rank' | 'serpt' | 'sr' | 'fifo' (index tables per paper).
      idx_table: optional precomputed (N, M) index table (overrides policy).
      stage_overhead: optional fixed checkpoint overhead added per stage
        (0 reproduces the paper; >0 models checkpoint save cost).
    """
    n = len(jobs)
    # Workload-keyed cache: padded arrays, stage durations and the policy
    # index table are computed once per workload, not once per trial.
    _, _, num_stages = policies.padded_arrays(jobs)
    stage_durs = policies.stage_durations(jobs)
    if idx_table is None:
        idx_table = policies.index_table(jobs, policy)
    outcomes = _realize_outcomes(jobs, rng)
    arrivals = np.array([j.arrival for j in jobs])

    # Event heap: (time, seq, kind, job).  kind: 0=arrival, 1=stage done.
    seq = itertools.count()
    events: list[tuple[float, int, int, int]] = [
        (float(arrivals[i]), next(seq), 0, i) for i in range(n)
    ]
    heapq.heapify(events)
    ready = ReadyQueue()

    stage = np.zeros(n, dtype=np.int64)  # stages completed so far
    free = n_servers
    completion = np.full(n, np.nan)
    makespan = 0.0

    def start(job: int, now: float) -> None:
        dur = float(stage_durs[job, stage[job]]) + stage_overhead
        heapq.heappush(events, (now + dur, next(seq), 1, job))

    # Events at the *same instant* are drained as one batch before any
    # dispatch, so simultaneous arrivals (the paper's static setting: all
    # jobs present at t=0) contend by policy index rather than by event
    # order — the min-index job starts first, ties by job position,
    # matching the exact evaluators' lockstep simulation.  At distinct
    # timestamps (the trace studies) the behavior is unchanged.
    while events:
        now, _, kind, job = heapq.heappop(events)
        makespan = max(makespan, now)
        batch = [(kind, job)]
        while events and events[0][0] == now:
            _, _, k2, j2 = heapq.heappop(events)
            batch.append((k2, j2))
        for kind, job in batch:
            if kind == 0:  # arrival: contend for a server by index
                ready.push(float(idx_table[job, stage[job]]), job)
            else:  # stage completed
                done_stage = stage[job]
                stage[job] += 1
                free += 1
                if done_stage == outcomes[job]:  # finished (success or term.)
                    completion[job] = now
                else:  # alive: re-compete with the queue at its new index
                    ready.push(float(idx_table[job, stage[job]]), job)
        while free > 0 and len(ready):
            free -= 1
            start(ready.pop(), now)

    success = outcomes == (num_stages - 1)
    sojourn = completion - arrivals
    assert not np.any(np.isnan(sojourn)), "all jobs must finish"
    return SimResult(
        mean_sojourn_successful=float(sojourn[success].mean()) if success.any() else 0.0,
        mean_sojourn_all=float(sojourn.mean()),
        n_success=int(success.sum()),
        n_jobs=n,
        makespan=float(makespan),
        policy=policy,
        n_servers=n_servers,
    )
