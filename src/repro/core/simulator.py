"""Discrete-event simulator for the online multi-server setting (paper §V-VI).

Implements the paper's online approach: W homogeneous servers; when a new
job arrives it is served immediately if a server is free, otherwise queued.
When a server completes a *stage* of a job, it serves the minimum-index job
among {ready queue} ∪ {the job it just served} — i.e. stage-boundary
preemption driven by a policy index table (rank / SERPT / SR / FIFO).

This is a thin frontend over the unified engine in
:mod:`repro.core.des.engine` (which also drives the cluster manager):
the hooks here are pure table lookups — policy index, padded stage
duration plus a fixed overhead, and a pre-realized outcome stage.
Events at the same instant are drained as one batch before dispatch, so
simultaneous arrivals (the paper's static setting: all jobs present at
t=0) contend by policy index, ties by job position — matching the exact
lockstep evaluators in :mod:`repro.kernels.sojourn_eval`.

The index is *conditional on progress*: a partially-served job competes
with its up-to-date conditional index (see
:func:`repro.core.policies.rank_index_table`).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import policies
from repro.core.des import ARRIVAL, Engine, ReadyQueue, SchedulerHooks  # noqa: F401
from repro.core.jobs import Workload

__all__ = ["SimResult", "ReadyQueue", "simulate"]


@dataclasses.dataclass
class SimResult:
    mean_sojourn_successful: float
    mean_sojourn_all: float
    n_success: int
    n_jobs: int
    makespan: float
    policy: str
    n_servers: int

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


def _realize_outcomes(jobs: Workload, rng: np.random.Generator | None) -> np.ndarray:
    out = np.empty(len(jobs), dtype=np.int64)
    for i, j in enumerate(jobs):
        if j.outcome_stage >= 0:
            out[i] = j.outcome_stage
        else:
            if rng is None:
                raise ValueError("jobs without fixed outcomes need an rng")
            out[i] = rng.choice(j.num_stages, p=j.probs)
    return out


class _TableHooks(SchedulerHooks):
    """Trace-study hooks: everything is a precomputed table lookup."""

    def __init__(self, idx_table, stage_durs, outcomes, num_stages, stage_overhead):
        self.idx_table = idx_table
        self.stage_durs = stage_durs
        self.outcomes = outcomes
        self.num_stages = num_stages
        self.stage_overhead = stage_overhead

    def index(self, job: int, stage: int) -> float:
        return float(self.idx_table[job, stage])

    def stage_duration(self, job: int, stage: int, now: float) -> float:
        return float(self.stage_durs[job, stage]) + self.stage_overhead

    def outcome(self, job: int) -> int:
        return int(self.outcomes[job])

    def is_success(self, job: int) -> bool:
        return bool(self.outcomes[job] == self.num_stages[job] - 1)


def simulate(
    jobs: Workload,
    n_servers: int,
    policy: str = "rank",
    idx_table: np.ndarray | None = None,
    rng: np.random.Generator | None = None,
    stage_overhead: float = 0.0,
    recorder=None,
    metrics=None,
) -> SimResult:
    """Run the online scheduler over a trace.

    Args:
      jobs: workload; each job's ``arrival`` is honored and its realized
        ``outcome_stage`` is used if set (trace-driven), else sampled.
      n_servers: W homogeneous servers.
      policy: 'rank' | 'serpt' | 'sr' | 'fifo' (index tables per paper).
      idx_table: optional precomputed (N, M) index table (overrides policy).
      stage_overhead: optional fixed checkpoint overhead added per stage
        (0 reproduces the paper; >0 models checkpoint save cost).
      recorder: optional :class:`repro.obs.TraceRecorder` (or any
        :class:`~repro.core.des.events.EngineObserver`) receiving the
        batched trace records; attaching one never changes results.
      metrics: optional :class:`repro.obs.MetricsRegistry` populated
        with the standard run metrics (sojourn percentiles by outcome,
        busy fraction, wasted work).
    """
    n = len(jobs)
    # Workload-keyed cache: padded arrays, stage durations and the policy
    # index table are computed once per workload, not once per trial.
    _, _, num_stages = policies.padded_arrays(jobs)
    stage_durs = policies.stage_durations(jobs)
    if idx_table is None:
        idx_table = policies.index_table(jobs, policy)
    outcomes = _realize_outcomes(jobs, rng)
    arrivals = np.array([j.arrival for j in jobs])

    eng = Engine(
        n,
        n_servers,
        _TableHooks(idx_table, stage_durs, outcomes, num_stages, stage_overhead),
        observer=recorder,
    )
    for i in range(n):
        eng.schedule(float(arrivals[i]), ARRIVAL, i)
    eng.run()

    success = outcomes == (num_stages - 1)
    sojourn = eng.completion - arrivals
    assert not np.any(np.isnan(sojourn)), "all jobs must finish"
    if metrics is not None:
        from repro.obs.metrics import record_run_metrics

        record_run_metrics(metrics, eng, arrivals, success)
    return SimResult(
        mean_sojourn_successful=float(sojourn[success].mean()) if success.any() else 0.0,
        mean_sojourn_all=float(sojourn.mean()),
        n_success=int(success.sum()),
        n_jobs=n,
        makespan=float(eng.makespan),
        policy=policy,
        n_servers=n_servers,
    )
