"""Core library: the paper's scheduling contribution as a composable module.

Public surface:

* :class:`repro.core.jobs.JobSpec` and workload generators (paper §IV-A2)
* :mod:`repro.core.policies` — RANK (Eq. 23), SERPT, SR/Gittins, FIFO,
  with conditional (stage-level) index tables
* :mod:`repro.core.evaluator` — exact / Monte-Carlo expected sojourn of
  successful jobs (JAX-vectorized), exhaustive OPTIMAL
* :mod:`repro.core.theory` — Theorem III.2 / Lemma III.3 numerics
* :mod:`repro.core.simulator` — multi-server online DES (paper §V)
* :mod:`repro.core.trace` — Philly-statistics trace synthesis (paper §VI-A)
"""

from repro.core.jobs import JobSpec, generate_workload, pad_workload  # noqa: F401
from repro.core.policies import (  # noqa: F401
    erpt_values,
    rank_order,
    rank_values,
    sr_rank_values,
)
from repro.core.evaluator import evaluate, evaluate_many, optimal_order  # noqa: F401
from repro.core.simulator import SimResult, simulate  # noqa: F401
from repro.core.trace import synthesize_trace  # noqa: F401
