"""Typed observer events for the unified discrete-event engine.

The engine (:mod:`repro.core.des.engine`) emits one *trace record* per
semantic scheduling action — arrival, dispatch, stage completion,
success/cancel exit, failure, restart (failure abort), resize.  Records
are flat tuples appended to an internal buffer and handed to observers
in **batches** (:class:`EngineObserver.on_events`), so million-event
replays pay one Python observer call per ``batch_size`` events instead
of per event.  :class:`TraceEvent` is the typed view of one record;
consumers that want structure (tests, exporters) decode on demand while
the hot path stays tuple-append cheap.

Every record carries a post-event snapshot of the scheduler state
(ready-queue length, busy/free server counts, resize target), so
batched consumers can check invariants and derive queue-depth /
utilization time series without touching live engine state.

The legacy observer form — a bare callable ``observer(engine, now)``
invoked per event — still works through :class:`LegacyObserverShim`
but raises a :class:`DeprecationWarning`; port call sites to
:class:`EngineObserver` (e.g. :class:`repro.obs.TraceRecorder`).
"""

from __future__ import annotations

import dataclasses
import warnings

__all__ = [
    "EV_ARRIVAL",
    "EV_DISPATCH",
    "EV_STAGE_DONE",
    "EV_COMPLETE",
    "EV_CANCEL",
    "EV_FAILURE",
    "EV_RESTART",
    "EV_RESIZE",
    "EVENT_NAMES",
    "TraceEvent",
    "EngineObserver",
    "LegacyObserverShim",
    "normalize_observers",
]

#: Trace-record kinds (richer than the engine's event-heap kinds: one
#: heap event can produce several trace records, e.g. a FAILURE heap
#: event emits EV_FAILURE plus an EV_RESTART for the aborted job).
(
    EV_ARRIVAL,
    EV_DISPATCH,
    EV_STAGE_DONE,
    EV_COMPLETE,
    EV_CANCEL,
    EV_FAILURE,
    EV_RESTART,
    EV_RESIZE,
) = range(8)

EVENT_NAMES = (
    "arrival",
    "dispatch",
    "stage_done",
    "complete",
    "cancel",
    "failure",
    "restart",
    "resize",
)

#: Field order of the flat record tuples the engine emits.
RECORD_FIELDS = (
    "time",
    "kind",
    "job",
    "stage",
    "value",
    "queue_len",
    "busy",
    "free",
    "target",
)


@dataclasses.dataclass(frozen=True, slots=True)
class TraceEvent:
    """Typed view of one engine trace record.

    ``job``/``stage`` are ``-1`` where not applicable (failure, resize).
    ``value`` is kind-specific: stage duration for ``dispatch``, abort
    span for ``restart``, new server target for ``resize``, else 0.
    ``queue_len``/``busy``/``free``/``target`` snapshot the scheduler
    state immediately *after* the event.
    """

    time: float
    kind: int
    job: int
    stage: int
    value: float
    queue_len: int
    busy: int
    free: int
    target: int

    @property
    def name(self) -> str:
        return EVENT_NAMES[self.kind]

    @classmethod
    def from_record(cls, record: tuple) -> "TraceEvent":
        return cls(*record)

    def as_record(self) -> tuple:
        return dataclasses.astuple(self)


class EngineObserver:
    """Batched observer protocol; subclass and override what you need.

    The engine buffers trace records and calls :meth:`on_events` with
    the buffered batch every ``batch_size`` records and once more at
    the end of the run, followed by :meth:`on_run_end`.  The records
    list is owned by the engine's flush — copy (or ``extend`` into your
    own storage) rather than holding a reference.
    """

    #: Records buffered between observer calls; the engine uses the
    #: minimum across its attached observers.
    batch_size: int = 4096

    def on_events(self, engine, records: list[tuple]) -> None:
        """A batch of flat trace records (see ``RECORD_FIELDS``)."""

    def on_run_end(self, engine) -> None:
        """The engine's event heap drained; the run is complete."""


class LegacyObserverShim:
    """Adapter for the deprecated ``observer(engine, now)`` callable form.

    The engine invokes legacy callables per event (never batched) so
    their historical contract — inspect live engine state after every
    handled event — keeps holding.
    """

    def __init__(self, fn):
        warnings.warn(
            "bare-callable engine observers (observer(engine, now)) are "
            "deprecated; subclass repro.core.des.events.EngineObserver "
            "(e.g. use repro.obs.TraceRecorder) for batched typed events",
            DeprecationWarning,
            stacklevel=3,
        )
        self.fn = fn

    def __call__(self, engine, now: float) -> None:
        self.fn(engine, now)


def normalize_observers(observer):
    """Split an observer spec into (legacy callables, batched observers).

    ``observer`` may be ``None``, a single observer, or a list/tuple
    mixing both styles; ``None`` entries are dropped.  Bare callables
    (anything without an ``on_events`` method) go through
    :class:`LegacyObserverShim` with a deprecation warning.
    """
    if observer is None:
        items = []
    elif isinstance(observer, (list, tuple)):
        items = [o for o in observer if o is not None]
    else:
        items = [observer]
    legacy, batched = [], []
    for o in items:
        if hasattr(o, "on_events"):
            batched.append(o)
        else:
            legacy.append(LegacyObserverShim(o))
    return legacy, batched
