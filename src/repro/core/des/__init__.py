"""Unified discrete-event scheduling engine (see docs/des_engine.md)."""

from repro.core.des.engine import (  # noqa: F401
    ARRIVAL,
    FAILURE,
    RESIZE,
    STAGE_DONE,
    Engine,
    ReadyQueue,
    ServerPool,
)
from repro.core.des.events import (  # noqa: F401
    EVENT_NAMES,
    EngineObserver,
    TraceEvent,
)
from repro.core.des.hooks import SchedulerHooks  # noqa: F401
