"""Hook protocol for the unified discrete-event engine.

The engine (:mod:`repro.core.des.engine`) owns the event heap, the
same-instant batch draining, the ready queue and the server pool; all
*policy* — which job goes first, how long a stage takes, what happens on
a node failure — is delegated to a :class:`SchedulerHooks` instance.
``core/simulator.py`` lowers onto it with pure table lookups;
``cluster/manager.py`` adds fault injection, straggler duplicate-and-race
and real-runner callbacks.  Both therefore share one contention
semantics, the one the fused lockstep evaluators replicate.

Observers are a separate surface: the engine emits typed, batched trace
records (:mod:`repro.core.des.events`) to
:class:`~repro.core.des.events.EngineObserver` instances — hooks decide
*behavior*, observers only *watch*.
"""

from __future__ import annotations

__all__ = ["SchedulerHooks"]


class SchedulerHooks:
    """Behavioral callbacks the engine invokes.  Subclass per frontend.

    Required overrides: :meth:`index`, :meth:`stage_duration`,
    :meth:`outcome`.  The rest default to no-ops.
    """

    # -- required ---------------------------------------------------------

    def index(self, job: int, stage: int) -> float:
        """Policy index of ``job`` about to serve ``stage`` (min first)."""
        raise NotImplementedError

    def stage_duration(self, job: int, stage: int, now: float) -> float:
        """Wall-clock duration of ``stage`` of ``job`` dispatched at ``now``.

        Called exactly once per dispatch, in dispatch order — stateful
        implementations (EWMA straggler detection, real runners) rely on
        that ordering.
        """
        raise NotImplementedError

    def outcome(self, job: int) -> int:
        """Realized stop stage of ``job`` (0-based).

        Read at stage-*completion* time, so implementations may revise it
        while the stage is in flight (e.g. a real runner's metric gate
        terminating the job early).
        """
        raise NotImplementedError

    # -- optional ---------------------------------------------------------

    def is_success(self, job: int) -> bool:
        """Whether ``job``'s realized outcome is a *success* (vs an early
        termination).  Classifies the exit trace record as ``complete``
        or ``cancel``; frontends that know the job's stage count override
        this with ``outcome(job) == num_stages - 1``.
        """
        return True

    def on_complete(self, job: int, now: float) -> None:
        """``job`` left the system at ``now`` (success or termination)."""

    def on_failure(self, engine, now: float) -> None:
        """A ``FAILURE`` event fired at ``now``.

        The hook owns the whole failure semantics: typically abort a
        running job via ``engine.abort(job)``, schedule its re-arrival,
        and re-arm the failure timer via ``engine.schedule``.  Engines
        without faults never schedule ``FAILURE`` events, so the default
        is a no-op.
        """
