"""Unified discrete-event engine for the online multi-server setting.

One event loop serves both frontends (paper §V–VI):

* :func:`repro.core.simulator.simulate` — the trace-study DES;
* :class:`repro.cluster.manager.ClusterManager` — faults, stragglers,
  elastic resize, real training jobs.

Semantics (the ones the fused lockstep evaluators in
:mod:`repro.kernels.sojourn_eval` replicate exactly):

* **Same-instant batch draining.**  All events with equal timestamps are
  drained as one batch *before* any dispatch, so simultaneous arrivals
  (the paper's static setting: all jobs present at t=0) contend by
  policy index rather than by event order; ties break by job position.
* **Stage-boundary preemption.**  A job that completes a stage and
  stays alive releases its server and re-competes with the whole ready
  queue at its updated conditional index (not just the queue head).
* **Drain-aware server pool.**  Elastic shrink retires servers at stage
  boundaries; every release path (stage completion *and* failure abort)
  checks the target, so ``len(running) + free <= target`` holds at every
  event and no server is leaked or double-freed.
"""

from __future__ import annotations

import heapq
import itertools

import numpy as np

from repro.core.des.hooks import SchedulerHooks

__all__ = [
    "ARRIVAL",
    "STAGE_DONE",
    "FAILURE",
    "RESIZE",
    "ReadyQueue",
    "ServerPool",
    "Engine",
]

# Event kinds.  ARRIVAL / re-arrival payload: job id.  STAGE_DONE payload:
# (job, epoch).  FAILURE payload: ignored.  RESIZE payload: new target.
ARRIVAL, STAGE_DONE, FAILURE, RESIZE = 0, 1, 2, 3


class ReadyQueue:
    """Priority queue of waiting jobs keyed by policy index (min first).

    Queued jobs never change stage, so indices never go stale; O(log N)
    push/pop as noted in the paper's Section V.  Ties break by insertion
    order, i.e. by job position for same-batch arrivals.
    """

    def __init__(self):
        self._heap: list[tuple[float, int, int]] = []
        self._seq = itertools.count()

    def push(self, index: float, job: int) -> None:
        heapq.heappush(self._heap, (index, next(self._seq), job))

    def pop(self) -> int:
        return heapq.heappop(self._heap)[2]

    def peek_index(self) -> float:
        return self._heap[0][0] if self._heap else np.inf

    def __len__(self) -> int:
        return len(self._heap)


class ServerPool:
    """W homogeneous servers with elastic resize and drain-at-boundary.

    ``len(running) + free <= target`` is an invariant at every event:
    grow adds free servers immediately; shrink retires idle servers
    immediately and busy ones as they release (stage completion or
    failure abort).
    """

    def __init__(self, n_servers: int):
        self.free = n_servers
        self.target = n_servers
        self.running: dict[int, int] = {}  # job -> dispatch epoch
        self._epoch = itertools.count()

    @property
    def busy(self) -> int:
        return len(self.running)

    def acquire(self, job: int) -> int:
        """Seize a free server for ``job``; returns the dispatch epoch."""
        if self.free <= 0:
            raise RuntimeError("acquire with no free server")
        if job in self.running:
            raise RuntimeError(f"job {job} dispatched twice")
        self.free -= 1
        ep = next(self._epoch)
        self.running[job] = ep
        return ep

    def release(self, job: int) -> None:
        """Return ``job``'s server; retire it instead if over target."""
        del self.running[job]
        if len(self.running) + self.free + 1 > self.target:
            return  # drain: shrink retires this server at the boundary
        self.free += 1

    def resize(self, target: int) -> None:
        self.target = target
        have = self.free + len(self.running)
        if target > have:
            self.free += target - have
        elif have > target:
            # retire idle servers now; busy ones drain on release
            self.free -= min(self.free, have - target)


class Engine:
    """Event heap + batch draining + dispatch; behavior via hooks.

    The caller seeds the heap with :meth:`schedule` (arrivals, resize
    events, the first failure timer) and calls :meth:`run`.  Per-job
    progress lives in ``stage`` (stages completed so far) and
    ``completion`` (exit time, NaN while in system).
    """

    def __init__(
        self,
        n_jobs: int,
        n_servers: int,
        hooks: SchedulerHooks,
        observer=None,
    ):
        self.n_jobs = n_jobs
        self.hooks = hooks
        self.observer = observer  # observer(engine, now) after each event
        self.pool = ServerPool(n_servers)
        self.ready = ReadyQueue()
        self.stage = np.zeros(n_jobs, dtype=np.int64)
        self.completion = np.full(n_jobs, np.nan)
        self.n_done = 0
        self.makespan = 0.0
        self._events: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()

    # -- caller API -------------------------------------------------------

    def schedule(self, t: float, kind: int, payload: object = None) -> None:
        heapq.heappush(self._events, (float(t), next(self._seq), kind, payload))

    def abort(self, job: int) -> None:
        """Abort ``job``'s in-flight stage (failure): free its server.

        Progress is not advanced; the pending ``STAGE_DONE`` goes stale
        via the epoch check.  The hook re-schedules the job's
        re-``ARRIVAL`` itself (e.g. after a checkpoint-restore window).
        """
        self.pool.release(job)

    def run(self) -> None:
        events = self._events
        while events:
            now, _, kind, payload = heapq.heappop(events)
            # An armed-but-idle failure timer is not work; everything
            # else (including a stale STAGE_DONE) extends the makespan.
            if kind != FAILURE:
                self.makespan = max(self.makespan, now)
            batch = [(kind, payload)]
            while events and events[0][0] == now:
                _, _, k2, p2 = heapq.heappop(events)
                if k2 != FAILURE:
                    self.makespan = max(self.makespan, now)
                batch.append((k2, p2))
            for kind, payload in batch:
                self._handle(kind, payload, now)
                if self.observer is not None:
                    self.observer(self, now)
            while self.pool.free > 0 and len(self.ready):
                self._start(self.ready.pop(), now)
            if self.observer is not None:
                self.observer(self, now)

    # -- internals --------------------------------------------------------

    def _handle(self, kind: int, payload: object, now: float) -> None:
        if kind == ARRIVAL:
            job = payload
            self.ready.push(self.hooks.index(job, int(self.stage[job])), job)
        elif kind == STAGE_DONE:
            job, epoch = payload
            if self.pool.running.get(job) != epoch:
                return  # stale: the job was aborted and re-dispatched
            self.pool.release(job)
            done_stage = int(self.stage[job])
            self.stage[job] += 1
            if done_stage == self.hooks.outcome(job):
                self.completion[job] = now
                self.n_done += 1
                self.hooks.on_complete(job, now)
            else:  # alive: re-compete with the whole queue (paper §V)
                self.ready.push(self.hooks.index(job, done_stage + 1), job)
        elif kind == RESIZE:
            self.pool.resize(payload)
        elif kind == FAILURE:
            self.hooks.on_failure(self, now)
        else:
            raise ValueError(f"unknown event kind {kind}")

    def _start(self, job: int, now: float) -> None:
        epoch = self.pool.acquire(job)
        dur = self.hooks.stage_duration(job, int(self.stage[job]), now)
        self.schedule(now + dur, STAGE_DONE, (job, epoch))
