"""Unified discrete-event engine for the online multi-server setting.

One event loop serves both frontends (paper §V–VI):

* :func:`repro.core.simulator.simulate` — the trace-study DES;
* :class:`repro.cluster.manager.ClusterManager` — faults, stragglers,
  elastic resize, real training jobs.

Semantics (the ones the fused lockstep evaluators in
:mod:`repro.kernels.sojourn_eval` replicate exactly):

* **Same-instant batch draining.**  All events with equal timestamps are
  drained as one batch *before* any dispatch, so simultaneous arrivals
  (the paper's static setting: all jobs present at t=0) contend by
  policy index rather than by event order; ties break by job position.
* **Stage-boundary preemption.**  A job that completes a stage and
  stays alive releases its server and re-competes with the whole ready
  queue at its updated conditional index (not just the queue head).
* **Drain-aware server pool.**  Elastic shrink retires servers at stage
  boundaries; every release path (stage completion *and* failure abort)
  checks the target, so ``len(running) + free <= target`` holds at every
  event and no server is leaked or double-freed.

Observability: the engine emits one flat trace record per scheduling
action (see :mod:`repro.core.des.events`) to attached
:class:`~repro.core.des.events.EngineObserver` instances, buffered and
dispatched in batches so tracing a million-event replay costs one
observer call per ``batch_size`` records.  With no observer attached,
no records are built.  Always-on aggregates (per-job service time,
aborted-work time, the time integral of the server target) are cheap
scalar updates and feed the metrics layer in :mod:`repro.obs`.
"""

from __future__ import annotations

import heapq
import itertools

import numpy as np

from repro.core.des.events import (
    EV_ARRIVAL,
    EV_CANCEL,
    EV_COMPLETE,
    EV_DISPATCH,
    EV_FAILURE,
    EV_RESIZE,
    EV_RESTART,
    EV_STAGE_DONE,
    normalize_observers,
)
from repro.core.des.hooks import SchedulerHooks

__all__ = [
    "ARRIVAL",
    "STAGE_DONE",
    "FAILURE",
    "RESIZE",
    "ReadyQueue",
    "ServerPool",
    "Engine",
]

# Event kinds.  ARRIVAL / re-arrival payload: job id.  STAGE_DONE payload:
# (job, epoch).  FAILURE payload: ignored.  RESIZE payload: new target.
ARRIVAL, STAGE_DONE, FAILURE, RESIZE = 0, 1, 2, 3


class ReadyQueue:
    """Priority queue of waiting jobs keyed by policy index (min first).

    Queued jobs never change stage, so indices never go stale; O(log N)
    push/pop as noted in the paper's Section V.  Ties break by insertion
    order, i.e. by job position for same-batch arrivals.
    """

    def __init__(self):
        self._heap: list[tuple[float, int, int]] = []
        self._seq = itertools.count()

    def push(self, index: float, job: int) -> None:
        heapq.heappush(self._heap, (index, next(self._seq), job))

    def pop(self) -> int:
        return heapq.heappop(self._heap)[2]

    def peek_index(self) -> float:
        return self._heap[0][0] if self._heap else np.inf

    def __len__(self) -> int:
        return len(self._heap)


class ServerPool:
    """W homogeneous servers with elastic resize and drain-at-boundary.

    ``len(running) + free <= target`` is an invariant at every event:
    grow adds free servers immediately; shrink retires idle servers
    immediately and busy ones as they release (stage completion or
    failure abort).
    """

    def __init__(self, n_servers: int):
        self.free = n_servers
        self.target = n_servers
        self.running: dict[int, int] = {}  # job -> dispatch epoch
        self._epoch = itertools.count()

    @property
    def busy(self) -> int:
        return len(self.running)

    def acquire(self, job: int) -> int:
        """Seize a free server for ``job``; returns the dispatch epoch."""
        if self.free <= 0:
            raise RuntimeError("acquire with no free server")
        if job in self.running:
            raise RuntimeError(f"job {job} dispatched twice")
        self.free -= 1
        ep = next(self._epoch)
        self.running[job] = ep
        return ep

    def release(self, job: int) -> None:
        """Return ``job``'s server; retire it instead if over target."""
        del self.running[job]
        if len(self.running) + self.free + 1 > self.target:
            return  # drain: shrink retires this server at the boundary
        self.free += 1

    def resize(self, target: int) -> None:
        self.target = target
        have = self.free + len(self.running)
        if target > have:
            self.free += target - have
        elif have > target:
            # retire idle servers now; busy ones drain on release
            self.free -= min(self.free, have - target)


class Engine:
    """Event heap + batch draining + dispatch; behavior via hooks.

    The caller seeds the heap with :meth:`schedule` (arrivals, resize
    events, the first failure timer) and calls :meth:`run`.  Per-job
    progress lives in ``stage`` (stages completed so far) and
    ``completion`` (exit time, NaN while in system).

    ``observer`` may be ``None``, an
    :class:`~repro.core.des.events.EngineObserver` (batched typed trace
    records), a deprecated bare callable ``observer(engine, now)``, or
    a list mixing both.
    """

    def __init__(
        self,
        n_jobs: int,
        n_servers: int,
        hooks: SchedulerHooks,
        observer=None,
    ):
        self.n_jobs = n_jobs
        self.hooks = hooks
        self.pool = ServerPool(n_servers)
        self.ready = ReadyQueue()
        self.stage = np.zeros(n_jobs, dtype=np.int64)
        self.completion = np.full(n_jobs, np.nan)
        self.n_done = 0
        self.makespan = 0.0
        self.now = 0.0
        # always-on aggregates for the metrics layer (cheap scalar math)
        self.service_time = np.zeros(n_jobs)  # completed-stage busy time
        self.aborted_time = 0.0  # busy time thrown away by failure aborts
        self._dispatch_time: dict[int, float] = {}
        self._target_integral = 0.0  # ∫ target dt over [0, makespan]
        self._t_target = 0.0
        self._events: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self._legacy, self._observers = normalize_observers(observer)
        self._emit = bool(self._observers)
        self._batch = (
            min(max(1, int(o.batch_size)) for o in self._observers)
            if self._observers
            else 0
        )
        self._buf: list[tuple] = []

    # -- caller API -------------------------------------------------------

    def schedule(self, t: float, kind: int, payload: object = None) -> None:
        heapq.heappush(self._events, (float(t), next(self._seq), kind, payload))

    def abort(self, job: int) -> None:
        """Abort ``job``'s in-flight stage (failure): free its server.

        Progress is not advanced; the pending ``STAGE_DONE`` goes stale
        via the epoch check.  The hook re-schedules the job's
        re-``ARRIVAL`` itself (e.g. after a checkpoint-restore window).
        """
        span = self.now - self._dispatch_time.pop(job)
        self.aborted_time += span
        self.pool.release(job)
        if self._emit:
            self._record(self.now, EV_RESTART, job, int(self.stage[job]), span)

    def run(self) -> None:
        events = self._events
        while events:
            now, _, kind, payload = heapq.heappop(events)
            self.now = now
            # An armed-but-idle failure timer is not work; everything
            # else (including a stale STAGE_DONE) extends the makespan.
            if kind != FAILURE:
                self.makespan = max(self.makespan, now)
            batch = [(kind, payload)]
            while events and events[0][0] == now:
                _, _, k2, p2 = heapq.heappop(events)
                if k2 != FAILURE:
                    self.makespan = max(self.makespan, now)
                batch.append((k2, p2))
            for kind, payload in batch:
                self._handle(kind, payload, now)
                for fn in self._legacy:
                    fn(self, now)
            while self.pool.free > 0 and len(self.ready):
                self._start(self.ready.pop(), now)
            for fn in self._legacy:
                fn(self, now)
        # close the server-target time integral at the makespan
        self._target_integral += self.pool.target * (self.makespan - self._t_target)
        self._t_target = self.makespan
        if self._emit:
            self._flush()
            for o in self._observers:
                o.on_run_end(self)

    @property
    def busy_time(self) -> float:
        """Total server-busy time (completed stages + aborted work)."""
        return float(self.service_time.sum()) + self.aborted_time

    @property
    def target_integral(self) -> float:
        """∫ server-target dt over the run (denominator of utilization)."""
        return self._target_integral

    # -- internals --------------------------------------------------------

    def _record(self, t: float, kind: int, job: int, stage: int, value: float):
        pool = self.pool
        self._buf.append(
            (t, kind, job, stage, value,
             len(self.ready), len(pool.running), pool.free, pool.target)
        )
        if len(self._buf) >= self._batch:
            self._flush()

    def _flush(self) -> None:
        buf = self._buf
        if not buf:
            return
        self._buf = []
        for o in self._observers:
            o.on_events(self, buf)

    def _handle(self, kind: int, payload: object, now: float) -> None:
        if kind == ARRIVAL:
            job = payload
            stage = int(self.stage[job])
            self.ready.push(self.hooks.index(job, stage), job)
            if self._emit:
                self._record(now, EV_ARRIVAL, job, stage, 0.0)
        elif kind == STAGE_DONE:
            job, epoch = payload
            if self.pool.running.get(job) != epoch:
                return  # stale: the job was aborted and re-dispatched
            self.service_time[job] += now - self._dispatch_time.pop(job)
            self.pool.release(job)
            done_stage = int(self.stage[job])
            self.stage[job] += 1
            if done_stage == self.hooks.outcome(job):
                self.completion[job] = now
                self.n_done += 1
                self.hooks.on_complete(job, now)
                if self._emit:
                    ev = EV_COMPLETE if self.hooks.is_success(job) else EV_CANCEL
                    self._record(now, ev, job, done_stage, 0.0)
            else:  # alive: re-compete with the whole queue (paper §V)
                self.ready.push(self.hooks.index(job, done_stage + 1), job)
                if self._emit:
                    self._record(now, EV_STAGE_DONE, job, done_stage, 0.0)
        elif kind == RESIZE:
            self._target_integral += self.pool.target * (now - self._t_target)
            self._t_target = now
            self.pool.resize(payload)
            if self._emit:
                self._record(now, EV_RESIZE, -1, -1, float(payload))
        elif kind == FAILURE:
            if self._emit:
                self._record(now, EV_FAILURE, -1, -1, 0.0)
            self.hooks.on_failure(self, now)
        else:
            raise ValueError(f"unknown event kind {kind}")

    def _start(self, job: int, now: float) -> None:
        epoch = self.pool.acquire(job)
        stage = int(self.stage[job])
        dur = self.hooks.stage_duration(job, stage, now)
        self._dispatch_time[job] = now
        self.schedule(now + dur, STAGE_DONE, (job, epoch))
        if self._emit:
            self._record(now, EV_DISPATCH, job, stage, dur)
