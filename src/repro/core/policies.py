"""Scheduling policies from the paper and its baselines.

Two kinds of policies exist in the paper:

* **Sequence policies** — produce a static non-preemptive order in which
  jobs run to success/termination (justified by Theorem III.1):
  RANK (the paper's contribution, Eq. 23), RANDOM, and OPTIMAL
  (exhaustive search, N <= 8).

* **Stage-level (dynamic) policies** — re-rank at every checkpoint and may
  preempt: SR (Gittins index, Eq. 2) and SERPT (shortest expected
  remaining processing time).  These are represented by *index tables*
  ``idx[i, s]`` = the job's priority index after having survived ``s``
  checkpoints; the scheduler always serves the alive job with the minimum
  index (ties by job position, matching the paper's deterministic runs).

All index computations are vectorized over the padded (N, M) workload
arrays so they can be reused by the JAX evaluator, the DES and the cluster
manager.
"""

from __future__ import annotations

import hashlib
import os
import re
import tempfile
import threading
from collections import OrderedDict

import numpy as np

from repro.core.jobs import Workload, pad_workload
from repro.obs import profiling as _prof

__all__ = [
    "workload_key",
    "workload_cached",
    "cache_stats",
    "reset_cache_stats",
    "default_cache_dir",
    "ensure_cache_dir",
    "padded_arrays",
    "stage_durations",
    "rank_values",
    "erpt_values",
    "sr_rank_values",
    "rank_order",
    "serpt_order",
    "random_order",
    "serpt_index_table",
    "sr_index_table",
    "rank_index_table",
    "SEQUENCE_POLICIES",
    "DYNAMIC_POLICIES",
]

_INF = np.float64(np.inf)


# ---------------------------------------------------------------------------
# Workload-keyed derived-data cache
# ---------------------------------------------------------------------------
#
# The DES (`simulator.py`) and the cluster manager re-derive the same
# padded arrays, stage-duration tables and policy index tables once per
# policy x trial.  All of those are pure functions of the workload's
# (sizes, probs, arrival) content, so we key a small LRU cache on a
# digest of those bytes and compute each derived table once per workload.
# Cached arrays are returned read-only; callers that need to mutate must
# copy.
#
# Setting ``REPRO_CACHE_DIR`` additionally memoizes the tables on disk
# (one ``.npz`` per (kind, workload) entry, written atomically), so
# sweep processes launched repeatedly over the same workloads skip the
# recomputation entirely.  The disk tier is size-bounded:
# ``REPRO_CACHE_DISK_BYTES`` (default 2 GiB; ``0`` or ``none`` disables
# the bound) caps the total ``.npz`` footprint with LRU eviction —
# loads refresh an entry's mtime, stores evict the stalest entries
# above the bound.  Disk traffic has its own hit/miss/eviction
# counters, folded into ``cache_stats`` only when the disk tier is
# exercised.

_CACHE_CAPACITY = 256
#: Default size bound of the on-disk tier (overridable via the
#: ``REPRO_CACHE_DISK_BYTES`` env var; ``0`` or ``none`` removes it).
_DISK_BYTES_DEFAULT = 2 << 30
_cache: OrderedDict[tuple[str, str], object] = OrderedDict()
_cache_lock = threading.Lock()
#: Counters per derived-table kind: [mem hits, mem misses, disk hits,
#: disk misses] (observability; see ``cache_stats`` and the benchmark
#: harness, which surfaces them).
_cache_stats: dict[str, list[int]] = {}
#: Entries removed from the disk tier by the LRU size bound.
_disk_evictions = 0


def default_cache_dir() -> str:
    """Default ``REPRO_CACHE_DIR`` for paper-scale sweep entry points."""
    root = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache"
    )
    return os.path.join(root, "repro-workloads")


def ensure_cache_dir(path: str | None = None) -> str:
    """Point ``REPRO_CACHE_DIR`` at a real directory and return it.

    Respects an existing ``REPRO_CACHE_DIR`` (only sets the default when
    unset), so sweep entry points (``benchmarks/run.py --full``, the
    DES/cluster examples) share one cross-process disk memo without
    clobbering explicit user configuration.
    """
    root = os.environ.setdefault("REPRO_CACHE_DIR", path or default_cache_dir())
    os.makedirs(root, exist_ok=True)
    return root


def workload_key(jobs: Workload) -> str:
    """Content digest of a workload (per-job sizes/probs/arrival)."""
    h = hashlib.sha1()
    for job in jobs:
        h.update(np.int64(job.num_stages).tobytes())
        h.update(np.asarray(job.sizes, dtype=np.float64).tobytes())
        h.update(np.asarray(job.probs, dtype=np.float64).tobytes())
        h.update(np.float64(job.arrival).tobytes())
    return h.hexdigest()


def _freeze(value):
    if isinstance(value, np.ndarray):
        value.flags.writeable = False
    elif isinstance(value, tuple):
        for v in value:
            if isinstance(v, np.ndarray):
                v.flags.writeable = False
    return value


def _disk_path(kind: str, digest: str) -> str | None:
    """Disk-memo path for a cache entry, or None if the tier is off."""
    root = os.environ.get("REPRO_CACHE_DIR")
    if not root:
        return None
    safe = re.sub(r"[^A-Za-z0-9._-]", "_", kind)
    return os.path.join(root, f"{safe}__{digest}.npz")


def _disk_limit_bytes() -> int | None:
    """Size bound of the disk tier in bytes; None when unbounded."""
    raw = os.environ.get("REPRO_CACHE_DISK_BYTES")
    if raw is None:
        return _DISK_BYTES_DEFAULT
    raw = raw.strip().lower()
    if raw in ("", "0", "none", "unbounded"):
        return None
    return int(raw)


def _disk_evict(root: str, keep: str) -> None:
    """LRU-evict ``.npz`` entries until the tier fits its size bound.

    Eviction order is mtime (oldest first): loads ``os.utime`` the entry
    they hit, so mtime is last-use recency.  ``keep`` (the entry just
    written) is never evicted.  Races with concurrent sweep processes
    are benign — a vanished file is simply skipped, an evicted entry is
    recomputed as a disk miss.
    """
    global _disk_evictions
    limit = _disk_limit_bytes()
    if limit is None:
        return
    t_prof = _prof.tick()
    entries = []
    total = 0
    try:
        names = os.listdir(root)
    except OSError:
        return
    for name in names:
        if not name.endswith(".npz"):
            continue
        path = os.path.join(root, name)
        try:
            st = os.stat(path)
        except OSError:
            continue
        entries.append((st.st_mtime, st.st_size, path))
        total += st.st_size
    entries.sort()
    for _, size, path in entries:
        if total <= limit:
            break
        if path == keep:
            continue
        try:
            os.unlink(path)
        except OSError:
            continue
        total -= size
        with _cache_lock:
            _disk_evictions += 1
    _prof.tock("cache.disk_evict", t_prof)


def _disk_load(path: str):
    """Load a memoized value; None if absent/unreadable (treated as miss)."""
    try:
        with np.load(path, allow_pickle=False) as z:
            items = [z[f"item_{i}"] for i in range(int(z["n_items"]))]
            scalars = z["scalars"]
            is_tuple = bool(z["is_tuple"])
    except (OSError, KeyError, ValueError):
        return None
    try:
        os.utime(path)  # refresh LRU recency for the size-bound eviction
    except OSError:
        pass
    items = [v.item() if s else v for v, s in zip(items, scalars)]
    return tuple(items) if is_tuple else items[0]


def _disk_store(path: str, value) -> None:
    """Atomically persist an ndarray or flat tuple of ndarrays/scalars."""
    items = value if isinstance(value, tuple) else (value,)
    payload = {"is_tuple": isinstance(value, tuple), "n_items": len(items)}
    scalars = []
    for i, v in enumerate(items):
        scalars.append(not isinstance(v, np.ndarray))
        payload[f"item_{i}"] = np.asarray(v)
    payload["scalars"] = np.asarray(scalars)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        suffix=".npz", prefix=".tmp_", dir=os.path.dirname(path) or "."
    )
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        return
    _disk_evict(os.path.dirname(path) or ".", keep=path)


def workload_cached(kind: str, jobs: Workload, compute):
    """Memoize ``compute()`` under ``(kind, workload_key(jobs))``.

    Two tiers: the in-process LRU, then (when ``REPRO_CACHE_DIR`` is
    set) a cross-process disk memo of one ``.npz`` per entry.  With
    :mod:`repro.obs.profiling` enabled, per-tier access latency is
    recorded (``prof.cache.mem_hit`` / ``disk_load`` / ``miss_compute``
    / ``disk_store`` / ``disk_evict`` histograms in the default
    metrics registry).
    """
    t_prof = _prof.tick()
    digest = workload_key(jobs)
    key = (kind, digest)
    with _cache_lock:
        counters = _cache_stats.setdefault(kind, [0, 0, 0, 0])
        if key in _cache:
            counters[0] += 1
            _cache.move_to_end(key)
            value = _cache[key]
            _prof.tock("cache.mem_hit", t_prof)
            return value
        counters[1] += 1
    path = _disk_path(kind, digest)
    value = _disk_load(path) if path else None
    if value is not None:
        with _cache_lock:
            counters[2] += 1
        value = _freeze(value)
        _prof.tock("cache.disk_load", t_prof)
    else:
        if path:
            with _cache_lock:
                counters[3] += 1
        t_compute = _prof.tick()
        value = _freeze(compute())
        _prof.tock("cache.miss_compute", t_compute)
        if path:
            t_store = _prof.tick()
            _disk_store(path, value)
            _prof.tock("cache.disk_store", t_store)
    with _cache_lock:
        _cache[key] = value
        _cache.move_to_end(key)
        while len(_cache) > _CACHE_CAPACITY:
            _cache.popitem(last=False)
    return value


def clear_workload_cache() -> None:
    with _cache_lock:
        _cache.clear()


def cache_stats() -> dict:
    """Hit/miss counters of the workload-keyed cache since the last reset.

    Returns ``{"hits": int, "misses": int, "hit_rate": float, "entries":
    int, "by_kind": {kind: {"hits": int, "misses": int}}}`` — a snapshot
    suitable for JSON artifacts (the benchmark harness attaches it to
    its output so sweep-scale cache behavior is observable).  When the
    ``REPRO_CACHE_DIR`` disk memo sees traffic, ``disk_hits`` /
    ``disk_misses`` counters are folded in at top level and per kind
    (in-memory misses that were served from disk count under both
    ``misses`` and ``disk_hits``).
    """
    with _cache_lock:
        by_kind = {}
        for kind, c in sorted(_cache_stats.items()):
            h, m, dh, dm = c
            entry = {"hits": h, "misses": m}
            if dh or dm:
                entry["disk_hits"] = dh
                entry["disk_misses"] = dm
            by_kind[kind] = entry
        hits = sum(c[0] for c in _cache_stats.values())
        misses = sum(c[1] for c in _cache_stats.values())
        disk_hits = sum(c[2] for c in _cache_stats.values())
        disk_misses = sum(c[3] for c in _cache_stats.values())
        entries = len(_cache)
    total = hits + misses
    stats = {
        "hits": hits,
        "misses": misses,
        "hit_rate": hits / total if total else 0.0,
        "entries": entries,
        "by_kind": by_kind,
    }
    if disk_hits or disk_misses:
        stats["disk_hits"] = disk_hits
        stats["disk_misses"] = disk_misses
    if _disk_evictions:
        stats["disk_evictions"] = _disk_evictions
    return stats


def reset_cache_stats() -> None:
    global _disk_evictions
    with _cache_lock:
        _cache_stats.clear()
        _disk_evictions = 0


def padded_arrays(jobs: Workload) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Cached ``pad_workload(jobs)``: (sizes (N,M), probs (N,M), num_stages)."""
    return workload_cached("padded", jobs, lambda: pad_workload(jobs))


def stage_durations(jobs: Workload) -> np.ndarray:
    """Cached (N, M) per-stage service increments (0 for padded stages)."""

    def compute():
        sizes, _, _ = padded_arrays(jobs)
        return np.diff(sizes, axis=1, prepend=0.0)

    return workload_cached("stage_durs", jobs, compute)


# ---------------------------------------------------------------------------
# Static (whole-job) indices
# ---------------------------------------------------------------------------


def erpt_values(jobs: Workload) -> np.ndarray:
    """ERPT(i) = sum_j x_{i,j} p_{i,j} (paper Section III-A)."""

    def compute():
        sizes, probs, _ = padded_arrays(jobs)
        return np.einsum("nm,nm->n", sizes, probs)

    return workload_cached("erpt_values", jobs, compute)


def rank_values(jobs: Workload) -> np.ndarray:
    """Paper Eq. (23): R(i) = E[size] / p_success."""

    def compute():
        sizes, probs, num_stages = padded_arrays(jobs)
        p_succ = probs[np.arange(len(jobs)), num_stages - 1]
        return np.einsum("nm,nm->n", sizes, probs) / p_succ

    return workload_cached("rank_values", jobs, compute)


def sr_rank_values(jobs: Workload) -> np.ndarray:
    """Paper Eq. (2): SR rank (equivalently the Gittins index) at stage 0."""
    return sr_index_table(jobs)[:, 0]


def rank_order(jobs: Workload) -> np.ndarray:
    """The RANK schedule: ascending R(i), stable in job position."""
    return workload_cached(
        "rank_order", jobs, lambda: np.argsort(rank_values(jobs), kind="stable")
    )


def serpt_order(jobs: Workload) -> np.ndarray:
    return workload_cached(
        "serpt_order", jobs, lambda: np.argsort(erpt_values(jobs), kind="stable")
    )


def random_order(jobs: Workload, rng: np.random.Generator) -> np.ndarray:
    return rng.permutation(len(jobs))


# ---------------------------------------------------------------------------
# Stage-level index tables  idx[i, s]  (s = checkpoints survived so far)
# ---------------------------------------------------------------------------


def _conditional_arrays(jobs: Workload):
    """Yield (i, s, rem_sizes, rem_probs) for every (job, survived-stage).

    ``surv`` (the probability of surviving the first ``s`` checkpoints)
    can round to <= 0 when the prefix mass sums to ~1 in float64; the
    clamp below keeps the conditional distribution finite (it reduces
    to the renormalized tail mass) instead of emitting inf/nan indices.
    """
    for i, job in enumerate(jobs):
        for s in range(job.num_stages):
            surv = 1.0 - job.probs[:s].sum()
            if surv <= 0.0:
                surv = max(
                    float(job.probs[s:].sum()), np.finfo(np.float64).tiny
                )
            base = job.sizes[s - 1] if s > 0 else 0.0
            rem_sizes = job.sizes[s:] - base
            rem_probs = job.probs[s:] / surv
            yield i, s, rem_sizes, rem_probs


def serpt_index_table(jobs: Workload) -> np.ndarray:
    """idx[i, s] = expected remaining processing time after s stages."""
    n = len(jobs)
    m = max(j.num_stages for j in jobs)
    table = np.full((n, m), _INF)
    for i, s, rem_sizes, rem_probs in _conditional_arrays(jobs):
        table[i, s] = float(np.dot(rem_sizes, rem_probs))
    return table


def sr_index_table(jobs: Workload) -> np.ndarray:
    """idx[i, s] = SR rank (Eq. 2) of the conditional remaining job."""
    n = len(jobs)
    m = max(j.num_stages for j in jobs)
    table = np.full((n, m), _INF)
    for i, s, rem_sizes, rem_probs in _conditional_arrays(jobs):
        cum_p = np.cumsum(rem_probs)
        cum_xp = np.cumsum(rem_sizes * rem_probs)
        # r = min_j [ sum_{k<=j} x_k p_k + x_j (1 - sum_{k<=j} p_k) ] / sum p_k
        num = cum_xp + rem_sizes * (1.0 - cum_p)
        table[i, s] = float(np.min(num / np.maximum(cum_p, 1e-300)))
    return table


def rank_index_table(jobs: Workload) -> np.ndarray:
    """idx[i, s] = conditional rank  E[rem size]/P(success | survived s).

    Used by the *online* approach (paper Section V) where partially-served
    jobs compete with queued ones by their up-to-date rank.
    """
    n = len(jobs)
    m = max(j.num_stages for j in jobs)
    table = np.full((n, m), _INF)
    for i, s, rem_sizes, rem_probs in _conditional_arrays(jobs):
        p_succ = rem_probs[-1]
        if p_succ > 0.0:
            table[i, s] = float(np.dot(rem_sizes, rem_probs) / p_succ)
        # else: zero conditional success probability — the rank (Eq. 23)
        # diverges, keep the +inf initialization rather than 0/0 = nan.
    return table


def fifo_index_table(jobs: Workload) -> np.ndarray:
    """idx[i, s] = arrival time (constant over stages): first-come-first-served."""
    n = len(jobs)
    m = max(j.num_stages for j in jobs)
    arr = np.array([j.arrival for j in jobs])
    return np.broadcast_to(arr[:, None], (n, m)).copy()


SEQUENCE_POLICIES = ("rank", "serpt", "random", "optimal")
DYNAMIC_POLICIES = {
    "sr": sr_index_table,
    "serpt": serpt_index_table,
    "rank": rank_index_table,
    "fifo": fifo_index_table,
}


def index_table(jobs: Workload, policy: str) -> np.ndarray:
    """Cached stage-level index table for ``policy``.

    Computed once per (policy, workload) instead of once per trial in the
    DES / cluster-manager sweeps.
    """
    try:
        fn = DYNAMIC_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown dynamic policy {policy!r}; options: {sorted(DYNAMIC_POLICIES)}"
        ) from None
    return workload_cached(f"idx_table:{policy}", jobs, lambda: fn(jobs))
