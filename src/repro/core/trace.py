"""Philly-like trace synthesis (paper Section VI-A).

The paper uses the Microsoft Philly trace (Jeon et al., ATC'19): 117,325
jobs over 75 days; 109,967 usable after filtering.  That CSV is not
redistributable in this offline container, so we synthesize a trace that
matches the published statistics the paper reports:

* attempt-count distribution (paper Table XV),
* job category split: 75% passed / 15% failed / 10% killed,
* 75-day arrival window (Poisson arrivals),
* heavy-tailed attempt durations (log-normal).

Mapping to the paper's job model: each *attempt* is a stage; a passed job
succeeds at its last observed stage; failed/killed jobs terminate early at
their last observed stage, and extra hypothetical stages (never executed)
are appended so the scheduler's size distribution extends beyond the
realized outcome — exactly the paper's construction.  Per-stage success
probabilities are sampled (uniform hazards), with the option to pin the
final success probability (synthetic data sets I and II use 0.5 / 0.25).

``load_trace_csv`` accepts a real Philly-style CSV when one is available,
so results can be regenerated on the true trace outside this container.
"""

from __future__ import annotations

import csv

import numpy as np

from repro.core.jobs import JobSpec

__all__ = ["synthesize_trace", "load_trace_csv", "ATTEMPT_COUNTS", "CATEGORY_PROBS"]

#: Paper Table XV (number of attempts -> job count).
ATTEMPT_COUNTS = {1: 95188, 2: 5465, 3: 1674, 4: 954, 5: 6574, 6: 67, 7: 1}

#: Paper Section VI-A: passed / failed / killed.
CATEGORY_PROBS = {"passed": 82445 / 109967, "failed": 16927 / 109967, "killed": 10595 / 109967}

#: Log-normal attempt-duration parameters (seconds).  Chosen so that the
#: offered load at the paper's server counts (5..300) spans the same
#: overloaded->stable regime as Tables XVI-XVIII (median ~25 min, heavy
#: tail; utilization ~0.9 at 300 servers, >>1 at 5-100).
DURATION_MU = np.log(1500.0)
DURATION_SIGMA = 1.9

#: Category correlates with attempt count (resubmissions indicate failure):
#: P(passed | attempts=a) = _PASS_BASE * _PASS_DECAY**(a-1), calibrated so
#: the marginal split stays ~75/15/10 under the Table XV attempt counts.
_PASS_BASE = 0.85
_PASS_DECAY = 0.3

SECONDS_PER_DAY = 86400.0


def _stage_probs(
    rng: np.random.Generator, m: int, success_prob: float | None
) -> np.ndarray:
    """Termination distribution over m stages via uniform per-checkpoint hazards."""
    if m == 1:
        return np.array([1.0])
    hazards = rng.uniform(0.0, 1.0, size=m - 1)
    probs = np.empty(m)
    surv = 1.0
    for j in range(m - 1):
        probs[j] = surv * hazards[j]
        surv *= 1.0 - hazards[j]
    probs[m - 1] = surv
    if success_prob is not None:
        # Pin p_M (synthetic sets I/II) and rescale the early mass.
        probs[: m - 1] *= (1.0 - success_prob) / max(probs[: m - 1].sum(), 1e-12)
        probs[m - 1] = success_prob
    return probs


def synthesize_trace(
    rng: np.random.Generator,
    n_jobs: int = 109_967,
    duration_days: float = 75.0,
    success_prob: float | None = None,
    extra_stages_max: int = 3,
) -> list[JobSpec]:
    """Generate a Philly-statistics-matched workload with realized outcomes."""
    attempts_vals = np.array(sorted(ATTEMPT_COUNTS))
    attempts_p = np.array([ATTEMPT_COUNTS[k] for k in attempts_vals], dtype=np.float64)
    attempts_p /= attempts_p.sum()

    arrivals = np.sort(rng.uniform(0.0, duration_days * SECONDS_PER_DAY, size=n_jobs))
    observed = rng.choice(attempts_vals, size=n_jobs, p=attempts_p)
    # category | attempts: repeated attempts indicate failure
    p_pass = _PASS_BASE * _PASS_DECAY ** (observed - 1)
    u = rng.uniform(size=n_jobs)
    fail_frac = CATEGORY_PROBS["failed"] / (
        CATEGORY_PROBS["failed"] + CATEGORY_PROBS["killed"]
    )
    category = np.where(
        u < p_pass, "passed",
        np.where(rng.uniform(size=n_jobs) < fail_frac, "failed", "killed"),
    )

    jobs = []
    for i in range(n_jobs):
        k = int(observed[i])
        if category[i] == "passed":
            m = k  # succeeds at its final observed stage
            outcome = m - 1
        else:
            # failed/killed: terminated at stage k; append hypothetical stages
            extra = int(rng.integers(1, extra_stages_max + 1))
            m = k + extra
            outcome = k - 1
        durs = rng.lognormal(DURATION_MU, DURATION_SIGMA, size=m)
        sizes = np.cumsum(np.maximum(durs, 1.0))
        probs = _stage_probs(rng, m, success_prob)
        jobs.append(
            JobSpec(
                sizes=sizes,
                probs=probs,
                arrival=float(arrivals[i]),
                job_id=i,
                outcome_stage=outcome,
            )
        )
    return jobs


def load_trace_csv(
    path: str,
    rng: np.random.Generator,
    success_prob: float | None = None,
    extra_stages_max: int = 3,
) -> list[JobSpec]:
    """Load a real trace CSV: columns job_id,arrival,category,attempt_durations.

    ``attempt_durations`` is a ';'-separated list of per-attempt seconds.
    The same stage/probability construction as :func:`synthesize_trace` is
    applied (paper Section VI-A).
    """
    jobs = []
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            durs = np.array([float(x) for x in row["attempt_durations"].split(";")])
            k = len(durs)
            if k == 0:
                continue
            if row["category"] == "passed":
                m, outcome = k, k - 1
            else:
                extra = int(rng.integers(1, extra_stages_max + 1))
                extra_durs = rng.lognormal(DURATION_MU, DURATION_SIGMA, size=extra)
                durs = np.concatenate([durs, extra_durs])
                m, outcome = k + extra, k - 1
            sizes = np.cumsum(np.maximum(durs, 1.0))
            jobs.append(
                JobSpec(
                    sizes=sizes,
                    probs=_stage_probs(rng, m, success_prob),
                    arrival=float(row["arrival"]),
                    job_id=int(row["job_id"]),
                    outcome_stage=outcome,
                )
            )
    return jobs
