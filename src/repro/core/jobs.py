"""Job model for multi-stage jobs with early termination.

A job i has M_i possible (cumulative) sizes 0 < x_{i,1} < ... < x_{i,M_i}
and termination probabilities p_{i,j} summing to 1.  Reaching size
x_{i,M_i} means the job completed *successfully*; stopping at any earlier
checkpoint x_{i,j}, j < M_i, is an early termination (unsuccessful).

This module is the data layer shared by the exact evaluators
(:mod:`repro.core.evaluator`), the policies (:mod:`repro.core.policies`),
the discrete-event simulator (:mod:`repro.core.simulator`) and the cluster
manager (:mod:`repro.cluster`).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "JobSpec",
    "Workload",
    "pad_workload",
    "generate_workload",
    "WORKLOAD_SETS",
    "sample_success_probs",
    "sample_stage_sizes",
]


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A single multi-stage job.

    Attributes:
      sizes: (M,) ascending cumulative checkpoint sizes; ``sizes[-1]`` is the
        full (successful) duration.
      probs: (M,) termination probabilities at each checkpoint; sum to 1.
        ``probs[-1]`` is the success probability.
      arrival: arrival time (0 for the static single-server problem).
      job_id: stable external identifier.
      outcome_stage: optional *realized* outcome (index into sizes) used by
        trace-driven simulation, where the ground truth is known but hidden
        from the scheduler.  -1 = sample at run time.
    """

    sizes: np.ndarray
    probs: np.ndarray
    arrival: float = 0.0
    job_id: int = -1
    outcome_stage: int = -1

    def __post_init__(self):
        sizes = np.asarray(self.sizes, dtype=np.float64)
        probs = np.asarray(self.probs, dtype=np.float64)
        object.__setattr__(self, "sizes", sizes)
        object.__setattr__(self, "probs", probs)
        if sizes.ndim != 1 or probs.shape != sizes.shape:
            raise ValueError("sizes/probs must be 1-D and same shape")
        if not np.all(np.diff(sizes) > 0):
            raise ValueError("sizes must be strictly ascending")
        if sizes[0] <= 0:
            raise ValueError("sizes must be positive")
        if np.any(probs < 0) or abs(probs.sum() - 1.0) > 1e-9:
            raise ValueError("probs must be a distribution")

    # -- derived quantities (Section II / III of the paper) ---------------

    @property
    def num_stages(self) -> int:
        return int(self.sizes.shape[0])

    @property
    def success_prob(self) -> float:
        """p_{i,M_i}."""
        return float(self.probs[-1])

    @property
    def erpt(self) -> float:
        """Expected (total) processing time  E[size] = sum_j x_j p_j."""
        return float(np.dot(self.sizes, self.probs))

    @property
    def rank(self) -> float:
        """Paper Eq. (23):  R(i) = E[size] / p_success."""
        return self.erpt / self.success_prob

    def stage_increments(self) -> np.ndarray:
        """Per-stage service increments delta_j = x_j - x_{j-1}."""
        return np.diff(self.sizes, prepend=0.0)

    def conditional(self, stages_done: int) -> "JobSpec":
        """Job as seen after surviving ``stages_done`` checkpoints.

        Remaining sizes are re-based at the current service point and
        probabilities renormalized; used by dynamic (stage-level) policies.
        """
        s = stages_done
        if not 0 <= s < self.num_stages:
            raise ValueError(f"stages_done={s} out of range")
        if s == 0:
            return self
        surv = 1.0 - self.probs[:s].sum()
        if surv <= 0:
            raise ValueError("job cannot have survived these stages")
        return JobSpec(
            sizes=self.sizes[s:] - self.sizes[s - 1],
            probs=self.probs[s:] / surv,
            arrival=self.arrival,
            job_id=self.job_id,
            outcome_stage=max(self.outcome_stage - s, -1)
            if self.outcome_stage >= 0
            else -1,
        )


Workload = Sequence[JobSpec]


def pad_workload(jobs: Workload) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad a workload to rectangular (N, M_max) arrays.

    Returns ``(sizes, probs, num_stages)`` where padded stage entries carry
    probability 0 and repeat the last size (so cumulative-size gathers stay
    well-defined).
    """
    n = len(jobs)
    m = max(j.num_stages for j in jobs)
    sizes = np.zeros((n, m), dtype=np.float64)
    probs = np.zeros((n, m), dtype=np.float64)
    num_stages = np.zeros((n,), dtype=np.int64)
    for i, j in enumerate(jobs):
        k = j.num_stages
        sizes[i, :k] = j.sizes
        sizes[i, k:] = j.sizes[-1]
        probs[i, :k] = j.probs
        num_stages[i] = k
    return sizes, probs, num_stages


# ---------------------------------------------------------------------------
# Workload generators (paper Section IV-A2, Table III)
# ---------------------------------------------------------------------------

#: Final-success-probability distribution I (paper Table I).
DIST_I_VALUES = np.arange(0.1, 1.0, 0.1)
DIST_I_PROBS = np.array([0.2, 0.15, 0.1, 0.05, 0.0, 0.05, 0.1, 0.15, 0.2])

#: Final-success-probability distribution II (paper Table II).
DIST_II_VALUES = np.arange(0.1, 1.0, 0.1)
DIST_II_PROBS = np.array([0.025, 0.05, 0.1, 0.15, 0.35, 0.15, 0.1, 0.05, 0.025])


def sample_success_probs(rng: np.random.Generator, n: int, kind: str) -> np.ndarray:
    """Sample final success probabilities p_{i,M_i}."""
    if kind == "uniform":
        return rng.uniform(1e-5, 1 - 1e-5, size=n)
    if kind == "dist1":
        return rng.choice(DIST_I_VALUES, size=n, p=DIST_I_PROBS / DIST_I_PROBS.sum())
    if kind == "dist2":
        return rng.choice(DIST_II_VALUES, size=n, p=DIST_II_PROBS / DIST_II_PROBS.sum())
    raise ValueError(f"unknown success-prob distribution {kind!r}")


def sample_stage_sizes(
    rng: np.random.Generator, n: int, m: int, kind: str
) -> np.ndarray:
    """Sample per-stage *increments*, returned as cumulative sizes (n, m)."""
    if kind == "uniform":
        inc = rng.uniform(0.0, 1.0, size=(n, m))
    elif kind == "exp":
        inc = rng.exponential(1.0, size=(n, m))
    elif kind == "weibull":
        # heavy tail: shape 0.5 as in the paper
        inc = rng.weibull(0.5, size=(n, m))
    else:
        raise ValueError(f"unknown stage-size distribution {kind!r}")
    inc = np.maximum(inc, 1e-9)  # sizes must be strictly ascending
    return np.cumsum(inc, axis=1)


#: Paper Table III: (stage-size dist, success-prob dist) per workload set.
WORKLOAD_SETS = {
    1: ("uniform", "uniform"),
    2: ("uniform", "dist1"),
    3: ("uniform", "dist2"),
    4: ("exp", "uniform"),
    5: ("weibull", "uniform"),
}


def generate_workload(
    rng: np.random.Generator,
    n_jobs: int,
    num_stages: int = 2,
    workload_set: int = 1,
    arrivals: np.ndarray | None = None,
) -> list[JobSpec]:
    """Generate one trial's job group per the paper's Section IV-A2.

    Final success probability is drawn from the set's distribution; the
    remaining mass ``1 - p_M`` is split over the M-1 early checkpoints with
    a symmetric Dirichlet (the paper does not pin this down for M > 2; for
    the paper's default M=2 it is exactly ``p_1 = 1 - p_2``).
    """
    size_kind, prob_kind = WORKLOAD_SETS[workload_set]
    sizes = sample_stage_sizes(rng, n_jobs, num_stages, size_kind)
    p_final = sample_success_probs(rng, n_jobs, prob_kind)
    jobs = []
    for i in range(n_jobs):
        if num_stages == 1:
            probs = np.array([1.0])
        elif num_stages == 2:
            probs = np.array([1.0 - p_final[i], p_final[i]])
        else:
            w = rng.dirichlet(np.ones(num_stages - 1))
            probs = np.concatenate([(1.0 - p_final[i]) * w, [p_final[i]]])
        jobs.append(
            JobSpec(
                sizes=sizes[i],
                probs=probs,
                arrival=0.0 if arrivals is None else float(arrivals[i]),
                job_id=i,
            )
        )
    return jobs
