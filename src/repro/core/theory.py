"""Numerics for the paper's theory: Q_{i,j}(l), alpha_{i,j}(N), R^N_{i,j}(d).

These implement the quantities of Theorem III.2 and Lemma III.3 exactly
(Poisson-binomial DP in float64), so tests can verify:

* the adjacent-exchange criterion ``R^N_{i,j}(i) < R^N_{i,j}(j)`` agrees
  with the sign of ``E[S*] - E[S']`` from the exact evaluator;
* ``alpha_{i,j}(N) -> 1`` as N grows (Lemma III.3) for i.i.d. success
  probabilities with ``1 < beta < inf``.
"""

from __future__ import annotations

import numpy as np

from repro.core.jobs import Workload, pad_workload

__all__ = [
    "poisson_binomial",
    "q_ij",
    "alpha_ij",
    "r_n",
    "beta_of",
]


def poisson_binomial(success_probs: np.ndarray) -> np.ndarray:
    """P[exactly l of the given independent Bernoullis succeed], l=0..n."""
    pmf = np.array([1.0])
    for p in success_probs:
        pmf = np.convolve(pmf, [1.0 - p, p])
    return pmf


def q_ij(jobs: Workload, i: int, j: int) -> np.ndarray:
    """Q_{i,j}(l): probability exactly l of the remaining N-2 jobs succeed."""
    _, probs, num_stages = pad_workload(jobs)
    p_succ = probs[np.arange(len(jobs)), num_stages - 1]
    others = np.delete(p_succ, [i, j])
    return poisson_binomial(others)


def alpha_ij(jobs: Workload, i: int, j: int) -> float:
    """Paper Eq. (4)."""
    n = len(jobs)
    q = q_ij(jobs, i, j)  # indices 0..N-2

    def q_at(l: int) -> float:
        return float(q[l]) if 0 <= l < len(q) else 0.0

    num = sum(q_at(l - 2) / l for l in range(2, n + 1))
    den = sum(q_at(l - 1) / l for l in range(1, n))
    return num / den


def r_n(jobs: Workload, i: int, j: int, d: int) -> float:
    """Paper Eq. (3): R^N_{i,j}(d)."""
    job = jobs[d]
    early = float(np.dot(job.sizes[:-1], job.probs[:-1]))
    return early / job.success_prob + alpha_ij(jobs, i, j) * float(job.sizes[-1])


def beta_of(success_probs: np.ndarray) -> float:
    """Empirical beta = E[p/(1-p)] (Lemma III.3's integral)."""
    p = np.asarray(success_probs, dtype=np.float64)
    return float(np.mean(p / (1.0 - p)))
