"""Data pipeline: deterministic synthetic LM streams + memmapped token files.

Design goals (scale-out):

* **Determinism under restart/elasticity** — batches are a pure function
  of (seed, step), never of worker state, so a job restarted from step k
  (fault tolerance) or re-sharded onto a different slice (elastic
  scaling) sees exactly the same token stream.
* **Shardability** — batches are produced host-side as numpy and placed
  with ``jax.device_put(batch, sharding)``; in a multi-host deployment
  each host materializes only its addressable shard (the per-host slice
  is again a pure function of (seed, step, shard_index)).
* **Model-agnostic** — the same batch dict feeds every architecture;
  encdec/vlm extras (stub frontend embeddings) are generated per-config.

The synthetic stream is a order-k Markov chain over the vocabulary with
hashed transitions — it has learnable structure (loss drops measurably
within hundreds of steps, used by the examples and the cluster manager's
early-termination metric gates) while requiring no data files.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax

__all__ = ["DataConfig", "SyntheticLM", "TokenFileDataset", "make_batch_specs"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 2
    pad_id: int = -1


class SyntheticLM:
    """Deterministic synthetic LM batches: hashed order-k Markov chain."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # hashed transition table: next = h(ctx) mixed with noise
        self._mix = np.uint64(0x9E3779B97F4A7C15)

    def _hash(self, x: np.ndarray) -> np.ndarray:
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))

    def batch(self, step: int) -> dict:
        """Batch for a global step: tokens (B, S), labels (B, S)."""
        cfg = self.cfg
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        rng = np.random.default_rng(np.uint64(cfg.seed) + np.uint64(step) * np.uint64(1000003))
        toks = np.empty((b, s), np.int64)
        toks[:, 0] = rng.integers(0, v, size=b)
        ctx = toks[:, 0].astype(np.uint64) + np.uint64(cfg.seed)
        noise = rng.integers(0, 16, size=(b, s))
        for t in range(1, s):
            h = self._hash(ctx * self._mix)
            # mostly-deterministic next token + small noise: learnable
            toks[:, t] = (h + noise[:, t].astype(np.uint64)) % np.uint64(v)
            ctx = self._hash(ctx ^ toks[:, t].astype(np.uint64))
        labels = np.concatenate([toks[:, 1:], np.full((b, 1), cfg.pad_id)], axis=1)
        return {
            "tokens": toks.astype(np.int32),
            "labels": labels.astype(np.int32),
        }


class TokenFileDataset:
    """Memmapped flat int32 token file, deterministic strided sampling."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(np.uint64(cfg.seed) + np.uint64(step))
        idx = rng.integers(0, self.n_windows, size=cfg.global_batch)
        starts = idx * cfg.seq_len
        toks = np.stack([self.tokens[s : s + cfg.seq_len] for s in starts])
        labels = np.stack([self.tokens[s + 1 : s + 1 + cfg.seq_len] for s in starts])
        return {"tokens": toks.astype(np.int32), "labels": labels.astype(np.int32)}


def make_batch_specs(cfg: DataConfig) -> dict:
    """ShapeDtypeStructs for a batch (dry-run input stand-ins)."""
    shape = (cfg.global_batch, cfg.seq_len)
    return {
        "tokens": jax.ShapeDtypeStruct(shape, np.int32),
        "labels": jax.ShapeDtypeStruct(shape, np.int32),
    }
