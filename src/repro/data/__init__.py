from repro.data.pipeline import DataConfig, SyntheticLM, TokenFileDataset, make_batch_specs  # noqa: F401
