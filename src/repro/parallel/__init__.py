"""Distribution plane: logical-axis sharding rules over the production mesh."""

from repro.parallel.sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    LONG_CONTEXT_RULES,
    logical_sharding,
    shard_pytree_spec,
    with_logical_constraint,
)
