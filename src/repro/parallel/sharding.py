"""Logical-axis partitioning (hand-rolled; no flax dependency).

Every parameter / activation in :mod:`repro.models` is annotated with a
tuple of *logical* axis names (e.g. ``("layers", "embed", "q_heads")``).
:class:`AxisRules` maps logical names to mesh axes; the same model code
then runs on any mesh — single device (all rules resolve to None), the
single-pod (16, 16) ``("data", "model")`` mesh, or the multi-pod
(2, 16, 16) ``("pod", "data", "model")`` mesh.

Sharding strategy (see DESIGN.md §5):

* tensor-parallel dims (heads / ffn / vocab / experts) -> ``"model"``
* FSDP: the ``"embed"`` dim of weight matrices -> ``("pod", "data")``
  so parameters and optimizer states are fully sharded (ZeRO-3).
* batch -> ``("pod", "data")``; sequence (SP, long-context) -> ``"data"``.

Rules silently drop mesh axes that are absent from the mesh, so the same
rule table serves both single-pod and multi-pod meshes.
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "LONG_CONTEXT_RULES",
    "logical_sharding",
    "shard_map",
    "shard_pytree_spec",
    "with_logical_constraint",
    "mesh_axis_sizes",
]


# ---------------------------------------------------------------------------
# shard_map version compat
# ---------------------------------------------------------------------------
#
# ``jax.shard_map`` only exists on newer JAX; older versions expose it as
# ``jax.experimental.shard_map.shard_map``.  The replication-check kwarg
# was also renamed (``check_rep`` -> ``check_vma``).  All repo call sites
# import from here and may use either kwarg name; we translate to whatever
# the installed JAX accepts.


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # noqa: PLC0415
    params = inspect.signature(fn).parameters
    return fn, params


def shard_map(f=None, /, **kwargs):
    """Version-portable ``shard_map`` (accepts check_rep or check_vma)."""
    fn, params = _resolve_shard_map()
    for old, new in (("check_rep", "check_vma"), ("check_vma", "check_rep")):
        if old in kwargs and old not in params and new in params:
            kwargs[new] = kwargs.pop(old)
    if f is None:
        return functools.partial(shard_map, **kwargs)
    return fn(f, **kwargs)

MeshAxes = tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: Mapping[str, str | MeshAxes | None]

    def resolve(self, logical: Sequence[str | None], mesh: Mesh) -> P:
        """PartitionSpec for a logical shape annotation on a given mesh.

        Mesh axes not present in ``mesh`` are dropped; a mesh axis may be
        used by at most one dim (first dim wins; later dims replicate),
        mirroring GSPMD validity requirements.
        """
        used: set[str] = set()
        out: list[Any] = []
        for name in logical:
            spec = self.rules.get(name) if name is not None else None
            if spec is None:
                out.append(None)
                continue
            axes = (spec,) if isinstance(spec, str) else tuple(spec)
            axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
            used.update(axes)
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(axes)
        # Trim trailing Nones (cosmetic; PartitionSpec semantics identical).
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def replace(self, **updates: str | MeshAxes | None) -> "AxisRules":
        merged = dict(self.rules)
        merged.update(updates)
        return AxisRules(merged)


#: Baseline rules: FSDP over (pod, data) + TP over model.
DEFAULT_RULES = AxisRules(
    {
        # -- parameter axes -------------------------------------------------
        "embed": ("pod", "data"),  # FSDP shard dim of every weight matrix
        "q_heads": "model",
        "kv_heads": None,  # kv_heads (8) < model axis (16): replicate
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",  # expert parallelism
        "expert_mlp": None,
        "ssm_heads": "model",
        "ssm_state": None,
        "conv_dim": "model",
        "layers": None,  # scan axis, never sharded
        # -- activation axes ------------------------------------------------
        "batch": ("pod", "data"),
        "seq": None,
        "kv_seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
    }
)

#: Long-context (batch=1) rules: sequence parallelism over "data".
LONG_CONTEXT_RULES = DEFAULT_RULES.replace(
    batch=None,
    seq="data",
    kv_seq="data",
)


def logical_sharding(
    logical: Sequence[str | None], mesh: Mesh, rules: AxisRules = DEFAULT_RULES
) -> NamedSharding:
    return NamedSharding(mesh, rules.resolve(logical, mesh))


def shard_pytree_spec(
    logical_tree: Any, mesh: Mesh, rules: AxisRules = DEFAULT_RULES
) -> Any:
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda logical: logical_sharding(logical, mesh, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def with_logical_constraint(
    x: jax.Array, logical: Sequence[str | None], rules: AxisRules, mesh: Mesh | None
) -> jax.Array:
    """`lax.with_sharding_constraint` by logical names; no-op off-mesh.

    Inside jit we can't query the ambient mesh, so callers thread the mesh
    (models receive it via ShardingCtx).  mesh=None disables constraints
    (single-device smoke tests).
    """
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(x, logical_sharding(logical, mesh, rules))


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Threaded through model code: mesh + active rule table.

    ``none()`` gives the no-constraint context used by unit tests.
    """

    mesh: Mesh | None
    rules: AxisRules = DEFAULT_RULES

    @staticmethod
    def none() -> "ShardingCtx":
        return ShardingCtx(mesh=None)

    def constrain(self, x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
        return with_logical_constraint(x, logical, self.rules, self.mesh)

    def sharding(self, logical: Sequence[str | None]) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return logical_sharding(logical, self.mesh, self.rules)


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, np.asarray(mesh.devices).shape))


def rules_for(
    cfg,
    *,
    long_context: bool = False,
    decode_batch: bool = False,
    model_axis: int = 16,
) -> AxisRules:
    """Per-architecture sharding rules (see DESIGN.md §5).

    * MoE with few experts (< model axis, e.g. Mixtral's 8): shard the
      expert FFN dim over "model" (TP-within-expert) instead of the expert
      axis — avoids GSPMD padding 8 experts onto 16 shards.
    * MoE with many experts (Kimi 384, Jamba 16): expert parallelism
      (experts over "model"), expert FFN dim replicated within a shard.
    * long_context (batch=1 decode): sequence parallelism — batch
      unsharded, (kv_)seq over "data".
    * decode_batch: KV-cache-resident serving (decode_32k) — the request
      batch shards over ("pod", "model") and the cache sequence over
      "data", so the cache is sharded over the whole mesh.  GSPMD
      decomposes the masked softmax over the sharded kv_seq into partial
      reductions + all-reduces (flash-decode by propagation).  This takes
      a decode_32k KV cache from 40 GiB/chip (batch over data only) to
      ~2.7 GiB/chip.
    """
    rules = LONG_CONTEXT_RULES if long_context else DEFAULT_RULES
    n_experts = getattr(cfg, "n_experts", 0)
    mode = getattr(cfg, "moe_ep", "auto")
    tp_experts = mode == "tp" or (mode == "auto" and 0 < n_experts < model_axis)
    if n_experts and tp_experts:
        rules = rules.replace(experts=None, expert_mlp="model")
    if decode_batch and not long_context:
        rules = rules.replace(batch=("pod", "model"), kv_seq="data")
    return rules


def serving_weight_rules(rules: AxisRules) -> AxisRules:
    """Serving layout (§Perf hillclimb A): TP-static weights + seq-sharded cache.

    The baseline decode layout FSDP-shards weights (embed over data) and
    batch over (pod, model): every decode step must all-gather weights
    over "data" AND reshard activations between the batch-parallel and
    head-parallel GEMM layouts — decode becomes collective-bound (75 GB
    of all-gather per token on granite, §Roofline).

    This layout instead keeps the big tensors static:
      * weights: embed replicated, heads/ffn/vocab over "model" (pure TP;
        per-chip weight bytes = params·2B/16 — fits every non-1T arch);
      * KV cache: batch over ("pod","data"), kv_seq over "model";
      * per-step collectives are then only the small activation psums
        (attention/MLP TP reductions and the sharded-softmax stats).
    """
    return rules.replace(embed=None, batch=("pod", "data"), kv_seq="model")
