"""Architecture registry: ``--arch <id>`` -> ModelConfig."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

__all__ = ["ARCHS", "get_config", "get_smoke", "list_archs"]

#: arch id -> module name under repro.configs
ARCHS = {
    "mixtral-8x22b": "mixtral_8x22b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-1.3b": "mamba2_1_3b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen3-8b": "qwen3_8b",
    "granite-3-8b": "granite_3_8b",
    "llama3-8b": "llama3_8b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def _module(arch: str):
    try:
        mod = ARCHS[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}") from None
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).CONFIG
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).SMOKE
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs() -> list[str]:
    return sorted(ARCHS)
