"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

24L d_model=1024 16H (GQA kv=16 = MHA) d_ff=8192 vocab=256206
[arXiv:2308.11596; hf].  Backbone only: the speech frontend is a stub
supplying precomputed frame embeddings (assignment contract); we model
24 encoder + 24 decoder layers with per-layer cross attention.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    tie_embeddings=False,
    frontend_frames=4096,  # overridden per shape
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=6,
    d_ff=192,
    vocab_size=512,
    tie_embeddings=False,
    frontend_frames=24,
    remat="none",
    attn_impl="xla",
)
