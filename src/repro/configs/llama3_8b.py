"""llama3-8b [dense] — GQA, 128k vocab.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[arXiv:2407.21783; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=448,
    vocab_size=512,
    tie_embeddings=False,
    remat="none",
    attn_impl="xla",
)
