"""The paper's own experiment configurations (Section IV / VI).

Not a neural architecture: the paper's workloads are job-size
distributions.  These configs drive benchmarks/run.py and the cluster
examples."""

from __future__ import annotations

import dataclasses

__all__ = ["NumericalStudy", "TraceStudy", "NUMERICAL", "TRACE"]


@dataclasses.dataclass(frozen=True)
class NumericalStudy:
    """Section IV setup: workload sets 1-5 (Table III)."""

    workload_sets: tuple[int, ...] = (1, 2, 3, 4, 5)
    n_jobs_sweep: tuple[int, ...] = (3, 4, 5, 6, 7, 8)  # OPTIMAL tractable
    n_jobs_extended: tuple[int, ...] = (3, 5, 7, 9, 11, 13, 15, 17)
    num_stages: int = 2
    stages_sweep: tuple[int, ...] = (2, 3, 4, 5, 6, 7, 8)  # Table XIV
    trials: int = 50_000  # paper: "at least 50000"
    trials_fast: int = 2_000  # CI-friendly subset
    algorithms: tuple[str, ...] = ("rank", "serpt", "sr", "random")


@dataclasses.dataclass(frozen=True)
class TraceStudy:
    """Section VI setup: Philly-statistics trace + synthetic variants."""

    n_jobs: int = 109_967
    duration_days: float = 75.0
    server_counts: tuple[int, ...] = (5, 10, 20, 50, 80, 100, 200, 300)
    policies: tuple[str, ...] = ("fifo", "serpt", "rank", "sr")
    synthetic_success_probs: tuple[float | None, ...] = (None, 0.5, 0.25)
    n_jobs_fast: int = 20_000  # CI-friendly subset


NUMERICAL = NumericalStudy()
TRACE = TraceStudy()
