"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8.

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert) vocab=163840,
MoE 384e top-8 [arXiv:2501.kimi2; unverified paper-table].

Analytic check: 61·384·3·7168·2048 ≈ 1.03e12 total params; active
(top-8) ≈ 3.0e10 + attention/embedding ≈ 32B — matches "1t-a32b".

Memory note (DESIGN.md §5): AdamW fp32 moments for 1.04T params do not
fit 512 v5e chips; this config defaults to bf16 moments + Adafactor for
the expert weights in train.py (documented in EXPERIMENTS.md §Dry-run).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    rope_theta=5e6,
    tie_embeddings=False,
    n_experts=384,
    top_k=8,
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke",
    family="moe",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    tie_embeddings=False,
    n_experts=16,  # > EINSUM_MAX_EXPERTS/4 still exercises top-8 routing
    top_k=8,
    capacity_factor=8.0,
    remat="none",
    attn_impl="xla",
    moe_impl="xla",
)
