"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified].  expand=2 -> d_inner=4096, headdim=64 ->
64 SSD heads, 1 B/C group, conv width 4.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=3,
    d_model=96,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=24,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=8,
    remat="none",
    ssd_impl="xla",
)
