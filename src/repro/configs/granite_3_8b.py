"""granite-3-8b [dense] — GQA.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=1e4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=4,
    d_ff=320,
    vocab_size=512,
    remat="none",
    attn_impl="xla",
)
