"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf]

Period of 8 (attn at offset 4, Jamba's attn_layer_period/offset); MoE on
odd positions (expert_layer_period=2, offset=1).  Mamba mixers use the
SSD form (state 16 as in Jamba's Mamba blocks, headdim 64 -> 128 heads).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    tie_embeddings=False,
    n_experts=16,
    top_k=2,
    moe_period=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    tie_embeddings=False,
    n_experts=4,
    top_k=2,
    capacity_factor=8.0,
    moe_period=2,
    moe_offset=1,
    attn_period=4,
    attn_offset=2,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=8,
    remat="none",
    attn_impl="xla",
    moe_impl="xla",
    ssd_impl="xla",
)
