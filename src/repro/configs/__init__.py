from repro.configs.registry import ARCHS, get_config, get_smoke, list_archs  # noqa: F401
from repro.configs.shapes import SHAPES, input_specs, runnable_cells  # noqa: F401
