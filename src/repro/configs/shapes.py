"""Assigned input shapes and the (arch × shape) cell matrix.

Shapes (LM family; seq_len × global_batch):
  train_4k     4,096 × 256   -> train_step
  prefill_32k  32,768 × 32   -> prefill (logits + serving cache)
  decode_32k   32,768 × 128  -> serve_step (1 new token, 32k KV cache)
  long_500k    524,288 × 1   -> serve_step, sequence-parallel cache

``long_500k`` requires sub-quadratic attention / bounded caches; pure
full-attention archs are documented skips (DESIGN.md §4):
  runs:  mamba2 (O(1) state), jamba (4/32 layers hold 500k KV, SP-sharded),
         mixtral (SWA ring cache, window 4096)
  skips: kimi-k2, qwen3-*, granite, llama3, llama-3.2-vision, seamless
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.registry import get_config, list_archs
from repro.models.config import ModelConfig

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "runnable_cells", "LONG_OK"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

#: archs whose long_500k cell is runnable (sub-quadratic / bounded cache)
LONG_OK = {"mamba2-1.3b", "jamba-v0.1-52b", "mixtral-8x22b"}


def arch_shape_config(arch: str, shape: str) -> ModelConfig:
    """Arch config specialized to a shape (frontend lengths track seq)."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    if cfg.family == "encdec":
        # encoder frames track the shape's sequence length
        cfg = dataclasses.replace(cfg, frontend_frames=spec.seq_len)
    return cfg


def input_specs(arch: str, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    train:   {tokens, labels}
    prefill: {tokens} (+ frontend extras)
    decode:  {token, pos} (+ cache specs are built by the launcher, which
             also owns their shardings)
    """
    cfg = arch_shape_config(arch, shape)
    spec = SHAPES[shape]
    b, s = spec.global_batch, spec.seq_len
    i32 = np.int32
    out: dict = {}
    if spec.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    elif spec.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode: one new token against a seq_len cache
        out["token"] = jax.ShapeDtypeStruct((b, 1), i32)
        out["pos"] = jax.ShapeDtypeStruct((), i32)
    if cfg.family == "encdec" and spec.kind != "decode":
        out["enc_frames"] = jax.ShapeDtypeStruct((b, cfg.frontend_frames, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm" and spec.kind != "decode":
        out["image_embeds"] = jax.ShapeDtypeStruct((b, cfg.num_image_tokens, cfg.d_model), cfg.dtype)
    return out


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells minus the documented long_500k skips."""
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            cells.append((arch, shape))
    return cells
