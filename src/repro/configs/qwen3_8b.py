"""qwen3-8b [dense] — qk_norm, GQA.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
[hf:Qwen/Qwen3-8B; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke",
    family="dense",
    n_layers=4,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=384,
    vocab_size=512,
    qk_norm=True,
    tie_embeddings=False,
    remat="none",
    attn_impl="xla",
)
