"""qwen3-1.7b [dense] — qk_norm, GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936
[hf:Qwen/Qwen3-8B; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen3-1.7b-smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    qk_norm=True,
    remat="none",
    attn_impl="xla",
)
