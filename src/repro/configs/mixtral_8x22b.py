"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2, SWA
[arXiv:2401.04088; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1e6,
    tie_embeddings=False,
    n_experts=8,
    top_k=2,
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    n_layers=4,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    sliding_window=32,
    tie_embeddings=False,
    n_experts=4,
    top_k=2,
    capacity_factor=8.0,
    remat="none",
    attn_impl="xla",
    moe_impl="xla",
)
