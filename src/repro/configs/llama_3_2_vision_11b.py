"""llama-3.2-vision-11b [vlm] — cross-attn image layers.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Backbone only (assignment contract): the ViT frontend is a stub supplying
precomputed patch embeddings (1601 tokens, padded to 1664 for clean
sharding).  8 gated cross-attention layers interleave with a period of 5
(one per period), matching the reference model's 8-in-40 layout.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    tie_embeddings=False,
    cross_attn_period=5,
    num_image_tokens=1664,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    family="vlm",
    n_layers=4,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    tie_embeddings=False,
    cross_attn_period=2,
    num_image_tokens=16,
    remat="none",
    attn_impl="xla",
)
