"""Observability tests: trace recorder, metrics registry, profiling hooks.

The load-bearing properties:

* attaching a :class:`TraceRecorder` NEVER changes scheduling results
  (bit-identical sojourns, traced vs untraced);
* the per-record state snapshots satisfy the scheduler invariants at
  every event under fault / straggler / resize interleavings;
* observer batching is invisible (batch_size 1 and 4096 produce the
  identical record stream);
* the Chrome-trace export passes the schema validator and the Gantt
  lanes never overlap.
"""

import json
import warnings

import numpy as np
import pytest

from repro.cluster.faults import FaultConfig
from repro.cluster.manager import ClusterManager, TrainingJob
from repro.core.des.events import (
    EV_DISPATCH,
    EVENT_NAMES,
    RECORD_FIELDS,
    TraceEvent,
)
from repro.core.jobs import generate_workload
from repro.core.simulator import simulate
from repro.obs import (
    MetricsRegistry,
    TraceRecorder,
    format_snapshot,
    profiling,
    validate_chrome_trace,
)
from repro.core.trace import synthesize_trace


def _trace_jobs(n=200, seed=0):
    rng = np.random.default_rng(seed)
    return synthesize_trace(rng, n_jobs=n, duration_days=0.4)


def _faulty_manager(recorder=None, metrics=None, seed=12):
    rng = np.random.default_rng(seed)
    spec = generate_workload(
        rng, 80, num_stages=3, workload_set=1,
        arrivals=np.sort(rng.uniform(0, 50.0, 80)),
    )
    tj = [TrainingJob(spec=s) for s in spec]
    cm = ClusterManager(
        tj, 8, rng=np.random.default_rng(seed),
        fault_cfg=FaultConfig(mtbf_hours=0.004, restart_overhead=0.1,
                              straggler_prob=0.2, straggler_slowdown=5.0,
                              deadline_factor=2.0),
        nodes_per_server=8,
        resize_events=[(2.0, 16), (6.0, 3), (10.0, 10)],
    )
    return cm, cm.run(recorder=recorder, metrics=metrics)


# ---------------------------------------------------------------------------
# tracing never perturbs results
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_servers", [1, 2, 3])
def test_recorder_leaves_simulate_bit_identical(n_servers):
    jobs = _trace_jobs()
    rec = TraceRecorder()
    traced = simulate(jobs, n_servers, "rank", recorder=rec)
    plain = simulate(jobs, n_servers, "rank")
    assert traced.mean_sojourn_successful == pytest.approx(
        plain.mean_sojourn_successful, rel=1e-9, abs=0.0
    )
    assert traced.mean_sojourn_all == pytest.approx(
        plain.mean_sojourn_all, rel=1e-9, abs=0.0
    )
    assert traced.makespan == plain.makespan
    assert traced.n_success == plain.n_success
    assert len(rec) > 0 and rec.n_runs == 1


def test_recorder_leaves_manager_bit_identical_under_faults():
    _, traced = _faulty_manager(recorder=TraceRecorder())
    _, plain = _faulty_manager()
    assert traced.mean_sojourn_successful == plain.mean_sojourn_successful
    assert traced.makespan == plain.makespan
    assert traced.restarts == plain.restarts


# ---------------------------------------------------------------------------
# invariants from the per-record state snapshots
# ---------------------------------------------------------------------------


def test_record_invariants_under_faults_and_resize():
    rec = TraceRecorder()
    _, res = _faulty_manager(recorder=rec)
    assert res.restarts > 0  # faults really interleaved
    counts = rec.counts()
    assert counts["restart"] == res.restarts
    assert counts["resize"] == 3
    assert counts["complete"] + counts["cancel"] == res.n_jobs
    for ev in rec.events():
        assert ev.queue_len >= 0, ev
        assert ev.free >= 0, ev
        assert ev.busy + ev.free <= ev.target, ev
        assert ev.time >= 0.0, ev


def test_record_times_are_nondecreasing():
    rec = TraceRecorder()
    simulate(_trace_jobs(), 3, "serpt", recorder=rec)
    times = [r[0] for r in rec.records]
    assert all(a <= b for a, b in zip(times, times[1:]))


def test_batch_size_is_invisible():
    jobs = _trace_jobs(120, seed=3)
    small, big = TraceRecorder(batch_size=1), TraceRecorder(batch_size=4096)
    simulate(jobs, 2, "rank", recorder=small)
    simulate(jobs, 2, "rank", recorder=big)
    assert small.records == big.records


def test_typed_event_round_trip():
    rec = TraceRecorder()
    simulate(_trace_jobs(40, seed=5), 2, "rank", recorder=rec)
    for r, ev in zip(rec.records, rec.events()):
        assert ev.as_record() == r
        assert ev.name == EVENT_NAMES[r[1]]
    assert len(RECORD_FIELDS) == len(rec.records[0])
    assert TraceEvent.from_record(rec.records[0]).time == rec.records[0][0]


# ---------------------------------------------------------------------------
# exports: Gantt, time series, Chrome trace
# ---------------------------------------------------------------------------


def test_gantt_lanes_never_overlap():
    rec = TraceRecorder()
    _faulty_manager(recorder=rec)
    rows = rec.gantt()
    dispatches = sum(1 for r in rec.records if r[1] == EV_DISPATCH)
    assert len(rows) == dispatches  # every dispatched stage span closed
    by_lane = {}
    for row in rows:
        assert row["end"] >= row["start"]
        by_lane.setdefault(row["server"], []).append((row["start"], row["end"]))
    assert len(by_lane) <= 16  # lane count bounded by peak target
    for spans in by_lane.values():
        spans.sort()
        for (_, e0), (s1, _) in zip(spans, spans[1:]):
            assert s1 >= e0, "overlapping spans on one server lane"


def test_series_shapes_and_values():
    rec = TraceRecorder()
    simulate(_trace_jobs(60, seed=7), 2, "rank", recorder=rec)
    qd = rec.queue_depth_series()
    ut = rec.utilization_series()
    assert qd.shape == (len(rec), 2) and ut.shape == (len(rec), 4)
    assert (qd[:, 1] >= 0).all()
    assert (ut[:, 1] <= ut[:, 3]).all()  # busy <= target


def test_chrome_trace_schema_and_validator(tmp_path):
    rec = TraceRecorder()
    _faulty_manager(recorder=rec)
    path = tmp_path / "trace.json"
    obj = rec.write_chrome_trace(str(path))
    with open(path) as f:
        assert json.load(f) == obj
    report = validate_chrome_trace(obj)
    assert report["events"] == len(obj["traceEvents"])
    assert report["by_phase"]["X"] == len(rec.gantt())
    assert obj["otherData"]["schema"] == "repro.obs/chrome-trace/v1"
    assert obj["otherData"]["counts"] == rec.counts()
    # validator actually rejects malformed traces
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "name": "x"}]})
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace({"traceEvents": [{"ph": "?"}]})
    with pytest.raises(ValueError, match="traceEvents array"):
        validate_chrome_trace({})


def test_recorder_accumulates_across_runs_and_clears():
    rec = TraceRecorder()
    jobs = _trace_jobs(30, seed=9)
    simulate(jobs, 2, "rank", recorder=rec)
    n1 = len(rec)
    simulate(jobs, 2, "sr", recorder=rec)
    assert len(rec) > n1 and rec.n_runs == 2
    rec.clear()
    assert len(rec) == 0 and rec.n_runs == 0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_registry_basics(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(4)
    reg.gauge("b").set(2.5)
    h = reg.histogram("c")
    h.observe_many(np.arange(100.0))
    h.observe(100.0)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 5
    assert snap["gauges"]["b"] == 2.5
    hs = snap["histograms"]["c"]
    assert hs["count"] == 101 and hs["min"] == 0.0 and hs["max"] == 100.0
    assert hs["p50"] == pytest.approx(50.0)
    assert hs["p99"] == pytest.approx(99.0)
    with pytest.raises(TypeError):
        reg.gauge("a")  # name already bound to a Counter
    path = tmp_path / "m.json"
    reg.to_json(str(path), run={"note": 1})
    doc = json.loads(path.read_text())
    assert doc["run"] == {"note": 1} and doc["counters"]["a"] == 5
    text = format_snapshot(snap)
    assert "a" in text and "p50" in text


def test_metrics_timer_records_seconds():
    reg = MetricsRegistry()
    with reg.timer("op"):
        pass
    snap = reg.snapshot()["histograms"]["op.seconds"]
    assert snap["count"] == 1 and snap["max"] >= 0.0


def test_simulate_fills_standard_metrics():
    reg = MetricsRegistry()
    res = simulate(_trace_jobs(150, seed=11), 3, "rank", metrics=reg)
    snap = reg.snapshot()
    assert snap["counters"]["jobs.total"] == 150
    assert snap["counters"]["jobs.successful"] == res.n_success
    assert snap["counters"]["jobs.canceled"] == 150 - res.n_success
    assert snap["histograms"]["sojourn.successful"]["count"] == res.n_success
    assert snap["gauges"]["run.makespan"] == res.makespan
    assert 0.0 < snap["gauges"]["servers.busy_fraction"] <= 1.0
    # no faults: nothing aborted, waste is exactly canceled-job service
    assert snap["gauges"]["work.aborted_time"] == 0.0
    assert snap["gauges"]["work.wasted"] >= 0.0
    assert snap["gauges"]["work.wasted"] <= snap["gauges"]["work.busy_time"]


def test_manager_fills_metrics_with_fault_counters():
    reg = MetricsRegistry()
    _, res = _faulty_manager(metrics=reg)
    snap = reg.snapshot()
    assert snap["counters"]["jobs.restarts"] == res.restarts > 0
    assert snap["gauges"]["work.aborted_time"] > 0.0
    assert snap["gauges"]["work.wasted"] >= snap["gauges"]["work.aborted_time"]
    assert 0.0 < snap["gauges"]["servers.busy_fraction"] <= 1.0


# ---------------------------------------------------------------------------
# legacy observer shim + profiling
# ---------------------------------------------------------------------------


def test_legacy_observer_warns_but_still_works():
    seen = []
    with pytest.warns(DeprecationWarning, match="deprecated"):
        simulate(_trace_jobs(20, seed=13), 2, "rank",
                 recorder=lambda eng, now: seen.append(now))
    assert seen and all(a <= b for a, b in zip(seen, seen[1:]))


def test_recorder_is_not_shimmed():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        simulate(_trace_jobs(20, seed=13), 2, "rank", recorder=TraceRecorder())


def test_profiling_spans_gate_on_enable():
    from repro.obs.metrics import get_registry

    was = profiling.enabled()
    try:
        profiling.enable(False)
        reg = MetricsRegistry()
        with profiling.span("off.case", registry=reg):
            pass
        assert reg.snapshot()["histograms"] == {}
        profiling.enable(True)
        with profiling.span("on.case", registry=reg):
            pass
        snap = reg.snapshot()
        assert snap["histograms"]["prof.on.case.seconds"]["count"] == 1
        assert snap["counters"]["prof.on.case.calls"] == 1
        t0 = profiling.tick()
        assert t0 > 0.0
        profiling.tock("probe.case", t0)
        d = get_registry().snapshot()
        assert d["counters"]["prof.probe.case.calls"] >= 1
        profiling.enable(False)
        assert profiling.tick() == 0.0
    finally:
        profiling.enable(was)


def test_profiled_sojourn_eval_records_span():
    from repro.core.evaluator import expected_sojourn_static
    from repro.core.policies import rank_order
    from repro.obs.metrics import get_registry

    jobs = generate_workload(np.random.default_rng(17), 5)
    was = profiling.enabled()
    try:
        profiling.enable(True)
        expected_sojourn_static(jobs, rank_order(jobs), impl="xla")
        snap = get_registry().snapshot()
        keys = [k for k in snap["histograms"]
                if k.startswith("prof.sojourn_eval.static.enum")]
        assert keys, snap["histograms"].keys()
    finally:
        profiling.enable(was)


# ---------------------------------------------------------------------------
# report CLI end-to-end
# ---------------------------------------------------------------------------


def test_report_cli_end_to_end(tmp_path, capsys):
    from repro.obs.report import main

    rc = main([
        "--jobs", "60", "--servers", "4", "--validate",
        "--resize", "20000", "2", "--out", str(tmp_path),
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "trace schema OK" in out and "run metrics" in out
    trace_obj = json.loads((tmp_path / "trace.json").read_text())
    validate_chrome_trace(trace_obj)
    doc = json.loads((tmp_path / "metrics.json").read_text())
    assert doc["counters"]["jobs.total"] == 60
    assert doc["run"]["counts"]["resize"] == 1
    assert "workload_cache" in doc and "hit_rate" in doc["workload_cache"]


def test_report_cli_overhead_bench_small(tmp_path, capsys):
    from repro.obs.report import main

    rc = main([
        "--jobs", "80", "--servers", "4", "--bench-overhead",
        "--out", str(tmp_path),
    ])
    assert rc == 0
    doc = json.loads((tmp_path / "metrics.json").read_text())
    ov = doc["run"]["overhead"]
    assert ov["events"] > 0 and ov["max_relerr"] <= 1e-9
