"""Parity suite for the fused dynamic-policy evaluator.

Four mutually independent implementations of "exact expected sojourn of
successful jobs under a stage-level index policy" must agree to <= 1e-9:

1. the fused streaming op (``sojourn_eval_dynamic``), XLA scan path and
   Pallas kernel in interpret mode;
2. the seed materialized lockstep simulation (``evaluator._dynamic_batch``,
   retained as the <= 2^21 reference tier);
3. the dense pure-Python oracle (``ref.ref_sojourn_dynamic``);
4. an exhaustive run of the unified discrete-event simulator
   (``simulate(..., n_servers=W)``) over every enumerated outcome.

All four implementations take ``n_servers``: the multi-server cases pin
the fused evaluator's W-server lockstep (busy-until registers, one
dispatch per completion) against the dict-of-finish-times oracle and
the DES engine's batched event heap.  Deterministic seeded cases run
here unconditionally; the hypothesis property-based version lives in
``test_differential.py``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import evaluator, policies, simulator
from repro.core.jobs import JobSpec, generate_workload
from repro.kernels.sojourn_eval import sojourn_eval_dynamic
from repro.kernels.sojourn_eval.ref import ref_sojourn_dynamic

RTOL = 1e-9
IMPLS = ("xla", "interpret")
POLICIES = ("sr", "serpt")


def _relerr(a, b):
    return abs(a - b) / max(abs(b), 1e-300)


def _tables(jobs, policy):
    _, probs, num_stages = policies.padded_arrays(jobs)
    durs = policies.stage_durations(jobs)
    idx = policies.index_table(jobs, policy)
    return probs, durs, num_stages, idx


def fused(jobs, policy, impl, n_servers=1):
    probs, durs, num_stages, idx = _tables(jobs, policy)
    with jax.experimental.enable_x64(True):
        es, ea = sojourn_eval_dynamic(
            probs, durs, num_stages, idx, n_servers=n_servers, impl=impl
        )
    return float(es[0]), float(ea[0])


def seed_batch(jobs, policy):
    """The materialized reference tier, fed the enumerated exact table."""
    probs, durs, num_stages, idx = _tables(jobs, policy)
    outcomes, weights = evaluator.enumerate_outcomes(jobs)
    _, success = evaluator._realized_arrays(jobs, outcomes)
    with jax.experimental.enable_x64(True):
        return float(
            evaluator._dynamic_batch(
                jnp.asarray(np.float64(idx)),
                jnp.asarray(np.float64(durs)),
                jnp.asarray(outcomes),
                jnp.asarray(success),
                jnp.asarray(np.float64(weights)),
                int(num_stages.sum()),
            )
        )


def oracle(jobs, policy, n_servers=1):
    probs, durs, num_stages, idx = _tables(jobs, policy)
    return ref_sojourn_dynamic(probs, durs, num_stages, idx, n_servers=n_servers)


def des_exhaustive(jobs, policy, n_servers=1):
    """Weight-average ``simulate(..., n_servers=W)`` over every outcome."""
    outcomes, weights = evaluator.enumerate_outcomes(jobs)
    total = 0.0
    for outcome, w in zip(outcomes, weights):
        fixed = [
            dataclasses.replace(j, outcome_stage=int(s))
            for j, s in zip(jobs, outcome)
        ]
        res = simulator.simulate(fixed, n_servers, policy)
        total += w * res.mean_sojourn_successful
    return total


# ---------------------------------------------------------------------------
# Four-way differential agreement (seeded; hypothesis version separately)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed,n,m", [(0, 3, 2), (1, 4, 3), (2, 5, 2), (3, 6, 3)])
def test_four_way_agreement(policy, seed, n, m):
    rng = np.random.default_rng(seed)
    jobs = generate_workload(rng, n, num_stages=m)
    ref_es, _ = oracle(jobs, policy)
    batch = seed_batch(jobs, policy)
    des = des_exhaustive(jobs, policy)
    assert _relerr(batch, ref_es) < RTOL
    assert _relerr(des, ref_es) < RTOL
    for impl in IMPLS:
        es, _ = fused(jobs, policy, impl)
        assert _relerr(es, ref_es) < RTOL, (impl, es, ref_es)
    # and the public evaluator entry rides the fused path
    assert _relerr(evaluator.expected_sojourn_dynamic(jobs, policy), ref_es) < RTOL


@pytest.mark.parametrize("policy", POLICIES)
def test_four_way_agreement_ragged(policy):
    """Ragged stage counts, a single-stage always-successful job, and a
    zero-probability outcome row, through all four implementations."""
    jobs = [
        JobSpec(sizes=np.array([1.0, 2.5]), probs=np.array([0.3, 0.7])),
        JobSpec(
            sizes=np.array([0.5, 1.0, 4.0, 6.0]),
            probs=np.array([0.1, 0.2, 0.3, 0.4]),
        ),
        JobSpec(sizes=np.array([2.0]), probs=np.array([1.0])),
        JobSpec(sizes=np.array([0.2, 0.9, 1.1]), probs=np.array([0.0, 0.6, 0.4])),
    ]
    ref_es, _ = oracle(jobs, policy)
    assert _relerr(seed_batch(jobs, policy), ref_es) < RTOL
    assert _relerr(des_exhaustive(jobs, policy), ref_es) < RTOL
    for impl in IMPLS:
        es, _ = fused(jobs, policy, impl)
        assert _relerr(es, ref_es) < RTOL, impl


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("n_servers", (2, 3))
@pytest.mark.parametrize("seed,n,m", [(0, 4, 2), (1, 5, 3), (2, 6, 2)])
def test_multi_server_four_way_agreement(policy, n_servers, seed, n, m):
    """W-server parity: fused (xla + interpret) vs dense oracle vs an
    exhaustive run of the unified DES, and the evaluator entry point."""
    rng = np.random.default_rng(seed)
    jobs = generate_workload(rng, n, num_stages=m)
    ref_es, _ = oracle(jobs, policy, n_servers=n_servers)
    des = des_exhaustive(jobs, policy, n_servers=n_servers)
    assert _relerr(des, ref_es) < RTOL
    for impl in IMPLS:
        es, _ = fused(jobs, policy, impl, n_servers=n_servers)
        assert _relerr(es, ref_es) < RTOL, (impl, es, ref_es)
    got = evaluator.expected_sojourn_dynamic(jobs, policy, n_servers=n_servers)
    assert _relerr(got, ref_es) < RTOL


@pytest.mark.parametrize("policy", POLICIES)
def test_servers_exceed_jobs_matches_parallel_service(policy):
    """W >= N: every job runs alone, so E[sojourn | success] is the
    probability-weighted mean over success patterns of per-job total
    sizes — checked against the oracle and monotonicity in W."""
    rng = np.random.default_rng(9)
    jobs = generate_workload(rng, 4, num_stages=3)
    ref_es, ref_ea = oracle(jobs, policy, n_servers=4)
    for w in (4, 6):  # saturated: more servers change nothing
        for impl in IMPLS:
            es, ea = fused(jobs, policy, impl, n_servers=w)
            assert _relerr(es, ref_es) < RTOL
            assert _relerr(ea, ref_ea) < RTOL
    # adding servers never hurts the all-jobs mean sojourn
    prev = float("inf")
    for w in (1, 2, 3, 4):
        _, ea = fused(jobs, policy, "xla", n_servers=w)
        assert ea <= prev + 1e-12
        prev = ea


@pytest.mark.parametrize("n_servers", (2, 3))
def test_multi_server_streamed_mc_matches_host_replay(n_servers):
    """samples= mode at W>1: the streamed outcomes evaluated in-kernel
    must match the host Threefry replay fed to the W-server oracle."""
    from repro.kernels.sojourn_eval.ref import ref_mc_outcomes

    rng = np.random.default_rng(23)
    jobs = generate_workload(rng, 5, num_stages=2)
    probs, durs, num_stages, idx = _tables(jobs, "sr")
    seed, n_samples = 77, 512
    outcomes, weights = ref_mc_outcomes(probs, num_stages, seed, n_samples)
    want_es, want_ea = ref_sojourn_dynamic(
        probs, durs, num_stages, idx,
        outcomes=outcomes, weights=weights, n_servers=n_servers,
    )
    with jax.experimental.enable_x64(True):
        for impl in IMPLS:
            es, ea = sojourn_eval_dynamic(
                probs, durs, num_stages, idx,
                samples=(seed, n_samples), n_servers=n_servers, impl=impl,
            )
            assert _relerr(float(es[0]), want_es) < RTOL, impl
            assert _relerr(float(ea[0]), want_ea) < RTOL, impl


def test_materialized_tier_rejects_multi_server():
    rng = np.random.default_rng(31)
    jobs = generate_workload(rng, 4)
    outcomes, weights = evaluator.enumerate_outcomes(jobs)
    with pytest.raises(ValueError, match="single-server"):
        evaluator.expected_sojourn_dynamic(
            jobs, "sr", outcomes=outcomes, weights=weights, n_servers=2
        )


# ---------------------------------------------------------------------------
# Kernel-level properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
def test_policy_batch_matches_single(impl):
    """A (P, N, M) stacked table call == per-policy calls."""
    rng = np.random.default_rng(5)
    jobs = generate_workload(rng, 5, num_stages=3)
    probs, durs, num_stages, _ = _tables(jobs, "sr")
    tabs = np.stack(
        [np.asarray(policies.index_table(jobs, p)) for p in POLICIES]
    )
    with jax.experimental.enable_x64(True):
        es_b, ea_b = sojourn_eval_dynamic(probs, durs, num_stages, tabs, impl=impl)
        for i, p in enumerate(POLICIES):
            es, ea = sojourn_eval_dynamic(
                probs, durs, num_stages, tabs[i], impl=impl
            )
            np.testing.assert_allclose(es[0], es_b[i], rtol=RTOL)
            np.testing.assert_allclose(ea[0], ea_b[i], rtol=RTOL)


@pytest.mark.parametrize("impl", IMPLS)
def test_fixed_priority_table_matches_static_order(impl):
    """An index table constant over stages == the static order it encodes
    (no preemption ever pays off), tying the dynamic kernel to the static
    fused evaluator."""
    rng = np.random.default_rng(7)
    jobs = generate_workload(rng, 5, num_stages=2)
    order = rng.permutation(5)
    table = np.zeros((5, 2))
    for pos, i in enumerate(order):
        table[i, :] = pos
    probs, durs, num_stages, _ = _tables(jobs, "sr")
    with jax.experimental.enable_x64(True):
        es, ea = sojourn_eval_dynamic(probs, durs, num_stages, table, impl=impl)
    want = evaluator.expected_sojourn_static(jobs, order, also_all_jobs=True)
    np.testing.assert_allclose(float(es[0]), float(want[0]), rtol=RTOL)
    np.testing.assert_allclose(float(ea[0]), float(want[1]), rtol=RTOL)


def test_multi_tile_grid_and_tail_masking():
    """K = 3^7 = 2187 spans 3 combination tiles with a ragged tail."""
    rng = np.random.default_rng(11)
    jobs = generate_workload(rng, 7, num_stages=3)
    ref_es, ref_ea = oracle(jobs, "serpt")
    for impl in IMPLS:
        es, ea = fused(jobs, "serpt", impl)
        assert _relerr(es, ref_es) < RTOL, impl
        assert _relerr(ea, ref_ea) < RTOL, impl


def test_n1_single_job():
    jobs = [JobSpec(sizes=np.array([1.0, 3.0]), probs=np.array([0.4, 0.6]))]
    ref_es, ref_ea = oracle(jobs, "sr")
    for impl in IMPLS:
        es, ea = fused(jobs, "sr", impl)
        assert _relerr(es, ref_es) < RTOL
        assert _relerr(ea, ref_ea) < RTOL
    # single job: E[sojourn | success] is its full size
    np.testing.assert_allclose(ref_es, 0.6 * 3.0, rtol=RTOL)


# ---------------------------------------------------------------------------
# Tiering: exactness beyond the materialization cap
# ---------------------------------------------------------------------------


def test_dynamic_exact_beyond_materialization_cap():
    """K = 2^22 > MAX_MATERIALIZED_COMBOS: enumerate_outcomes refuses, but
    the fused dynamic path evaluates exactly in bounded memory, and
    evaluate_many keeps SR exact instead of falling back to MC."""
    rng = np.random.default_rng(13)
    jobs = generate_workload(rng, 22)  # 2^22 combinations
    assert evaluator.exact_combination_count(jobs) == 2**22
    with pytest.raises(ValueError, match="MAX_MATERIALIZED_COMBOS"):
        evaluator.enumerate_outcomes(jobs)
    val = evaluator.expected_sojourn_dynamic(jobs, "sr")
    assert np.isfinite(val) and val > 0
    # cross-check against an independent MC estimate (loose tolerance)
    mc_o, mc_w = evaluator.sample_outcomes(jobs, 20_000, rng)
    mc = evaluator.expected_sojourn_dynamic(jobs, "sr", outcomes=mc_o, weights=mc_w)
    assert abs(mc - val) / val < 0.05


def test_dynamic_rejects_beyond_exact_cap():
    rng = np.random.default_rng(17)
    jobs = generate_workload(rng, 27)  # 2^27 > MAX_EXACT_COMBOS
    with pytest.raises(ValueError, match="MAX_EXACT_COMBOS"):
        evaluator.expected_sojourn_dynamic(jobs, "sr")


def test_evaluate_many_all_exact_within_cap():
    """At K <= MAX_EXACT_COMBOS no policy uses MC: repeated calls with
    different rngs give identical values."""
    rng = np.random.default_rng(19)
    jobs = generate_workload(rng, 6, num_stages=3)
    a = evaluator.evaluate_many(jobs, ("rank", "sr", "serpt"), np.random.default_rng(0))
    b = evaluator.evaluate_many(jobs, ("rank", "sr", "serpt"), np.random.default_rng(1))
    assert a == b
    assert _relerr(a["sr"], oracle(jobs, "sr")[0]) < RTOL
