"""Multi-server DES: paper Section V example + conservation invariants."""

import numpy as np
import pytest

from repro.core import policies, simulator, trace
from repro.core.jobs import JobSpec


def test_paper_section_v_example():
    """Single server, two jobs with arrivals — schedule from the paper text:
    job1 stage1 [0,4]; job2 both stages [4,6]; job1 stage2 [6,12]."""
    j1 = JobSpec(sizes=[4, 10], probs=[0.4, 0.6], arrival=0.0, job_id=0, outcome_stage=1)
    j2 = JobSpec(sizes=[1, 2], probs=[0.2, 0.8], arrival=2.0, job_id=1, outcome_stage=1)
    res = simulator.simulate([j1, j2], 1, "rank")
    assert res.n_success == 2
    # sojourns: job1 = 12-0, job2 = 6-2
    assert res.mean_sojourn_successful == pytest.approx((12 + 4) / 2)


def test_all_jobs_complete_and_success_count():
    rng = np.random.default_rng(0)
    jobs = trace.synthesize_trace(rng, n_jobs=500, duration_days=1)
    n_pass = sum(j.outcome_stage == j.num_stages - 1 for j in jobs)
    for pol in ("fifo", "serpt", "rank", "sr"):
        res = simulator.simulate(jobs, 10, pol)
        assert res.n_jobs == 500
        assert res.n_success == n_pass  # outcomes are schedule-independent


def test_fifo_never_preempts():
    """With FIFO indices, a running job always wins stage-boundary contests,
    so completion order of same-server jobs follows arrival order."""
    jobs = [
        JobSpec(sizes=[5, 6], probs=[0.5, 0.5], arrival=0.0, job_id=0, outcome_stage=1),
        JobSpec(sizes=[1, 2], probs=[0.5, 0.5], arrival=1.0, job_id=1, outcome_stage=1),
    ]
    res = simulator.simulate(jobs, 1, "fifo")
    # job0 runs [0,6] uninterrupted; job1 [6,8]: sojourns 6 and 7.
    assert res.mean_sojourn_successful == pytest.approx(6.5)


def test_more_servers_help_under_load():
    rng = np.random.default_rng(1)
    jobs = trace.synthesize_trace(rng, n_jobs=2000, duration_days=2)
    r5 = simulator.simulate(jobs, 5, "rank")
    r50 = simulator.simulate(jobs, 50, "rank")
    assert r50.mean_sojourn_successful < r5.mean_sojourn_successful


def test_rank_beats_fifo_on_trace():
    rng = np.random.default_rng(2)
    jobs = trace.synthesize_trace(rng, n_jobs=3000, duration_days=3)
    fifo = simulator.simulate(jobs, 20, "fifo")
    rank = simulator.simulate(jobs, 20, "rank")
    assert rank.mean_sojourn_successful < fifo.mean_sojourn_successful


def test_stage_overhead_increases_sojourn():
    rng = np.random.default_rng(3)
    jobs = trace.synthesize_trace(rng, n_jobs=500, duration_days=1)
    base = simulator.simulate(jobs, 10, "rank")
    slow = simulator.simulate(jobs, 10, "rank", stage_overhead=120.0)
    assert slow.mean_sojourn_successful > base.mean_sojourn_successful


def test_precomputed_index_table_matches_policy():
    rng = np.random.default_rng(4)
    jobs = trace.synthesize_trace(rng, n_jobs=300, duration_days=1)
    table = policies.index_table(jobs, "serpt")
    a = simulator.simulate(jobs, 8, "serpt")
    b = simulator.simulate(jobs, 8, "ignored", idx_table=table)
    assert a.mean_sojourn_successful == pytest.approx(b.mean_sojourn_successful)


def test_trace_statistics_match_published():
    rng = np.random.default_rng(5)
    jobs = trace.synthesize_trace(rng, n_jobs=20_000)
    n_pass = sum(j.outcome_stage == j.num_stages - 1 for j in jobs)
    assert n_pass / len(jobs) == pytest.approx(trace.CATEGORY_PROBS["passed"], abs=0.02)
    # ~86.6% of jobs have a single observed attempt (Table XV): passed 1-stage
    # jobs have num_stages == 1.
    one_attempt = trace.ATTEMPT_COUNTS[1] / sum(trace.ATTEMPT_COUNTS.values())
    single = sum(
        1
        for j in jobs
        if (j.outcome_stage == j.num_stages - 1 and j.num_stages == 1)
        or (j.outcome_stage == 0 and j.num_stages > 1)
    )
    assert single / len(jobs) == pytest.approx(one_attempt, abs=0.02)


def test_synthetic_success_prob_pinning():
    rng = np.random.default_rng(6)
    jobs = trace.synthesize_trace(rng, n_jobs=200, success_prob=0.25)
    for j in jobs:
        if j.num_stages > 1:
            assert j.probs[-1] == pytest.approx(0.25)
