"""Exact evaluator invariants + oracle cross-checks."""

import itertools

import numpy as np
import pytest

from repro.core import evaluator, policies
from repro.core.jobs import JobSpec, generate_workload


def _oracle_static(jobs, order):
    """Direct (slow) enumeration oracle for a static order, pure Python."""
    total = 0.0
    for combo in itertools.product(*[range(j.num_stages) for j in jobs]):
        w = np.prod([jobs[i].probs[c] for i, c in enumerate(combo)])
        t = 0.0
        comp = {}
        for pos in order:
            t += jobs[pos].sizes[combo[pos]]
            comp[pos] = t
        succ = [i for i, c in enumerate(combo) if c == jobs[i].num_stages - 1]
        if succ:
            total += w * np.mean([comp[i] for i in succ])
    return total


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("stages", [2, 3])
def test_static_evaluator_matches_oracle(seed, stages):
    rng = np.random.default_rng(seed)
    jobs = generate_workload(rng, 5, stages, 1)
    order = rng.permutation(5)
    got = evaluator.expected_sojourn_static(jobs, order)
    want = _oracle_static(jobs, order)
    assert got == pytest.approx(want, rel=1e-5)


def test_weights_sum_to_one():
    rng = np.random.default_rng(3)
    jobs = generate_workload(rng, 6, 3, 4)
    _, weights = evaluator.enumerate_outcomes(jobs)
    assert weights.sum() == pytest.approx(1.0)


def test_optimal_lower_bounds_all_policies():
    rng = np.random.default_rng(4)
    for _ in range(5):
        jobs = generate_workload(rng, 6, 2, 1)
        _, e_opt = evaluator.optimal_order(jobs)
        for pol in ("rank", "serpt", "sr"):
            assert evaluator.evaluate(jobs, pol) >= e_opt - 1e-6


def test_rank_near_optimal_small_n():
    # Paper Tables IV-VIII: RANK within ~0.2% of OPTIMAL on average;
    # check a loose per-instance bound (max CR <= ~1.12 in paper Table IX).
    rng = np.random.default_rng(5)
    ratios = []
    for _ in range(40):
        jobs = generate_workload(rng, 6, 2, 1)
        _, e_opt = evaluator.optimal_order(jobs)
        ratios.append(evaluator.evaluate(jobs, "rank") / e_opt)
    assert np.mean(ratios) < 1.01
    assert np.max(ratios) < 1.15


def test_relabeling_invariance():
    rng = np.random.default_rng(6)
    jobs = generate_workload(rng, 6, 2, 2)
    perm = rng.permutation(6)
    relabeled = [jobs[p] for p in perm]
    # order in the original labeling vs the same physical order relabeled
    order = rng.permutation(6)
    inv = np.argsort(perm)
    e1 = evaluator.expected_sojourn_static(jobs, order)
    e2 = evaluator.expected_sojourn_static(relabeled, inv[order])
    assert e1 == pytest.approx(e2, rel=1e-6)


def test_dynamic_fixed_order_matches_static():
    """A dynamic index table encoding a fixed priority == static order."""
    rng = np.random.default_rng(7)
    jobs = generate_workload(rng, 5, 2, 1)
    order = rng.permutation(5)
    # index[i, s] = position of i in order (constant over stages) -> jobs run
    # in exactly that sequence (no preemption: running job keeps min index).
    table = np.zeros((5, 2))
    for pos, i in enumerate(order):
        table[i, :] = pos
    got = evaluator.expected_sojourn_dynamic(jobs, "sr")  # warm policy path
    dyn = evaluator._dynamic_batch  # reuse internals with a custom table
    import jax.numpy as jnp

    from repro.core.jobs import pad_workload

    sizes, _, num_stages = pad_workload(jobs)
    outcomes, weights = evaluator.enumerate_outcomes(jobs)
    _, success = evaluator._realized_arrays(jobs, outcomes)
    val = float(
        dyn(
            jnp.asarray(table),
            jnp.asarray(np.diff(sizes, axis=1, prepend=0.0)),
            jnp.asarray(outcomes),
            jnp.asarray(success),
            jnp.asarray(weights),
            int(num_stages.sum()),
        )
    )
    want = evaluator.expected_sojourn_static(jobs, order)
    assert val == pytest.approx(want, rel=1e-5)
    assert np.isfinite(got)


def test_monte_carlo_approaches_exact():
    rng = np.random.default_rng(8)
    jobs = generate_workload(rng, 6, 2, 1)
    exact = evaluator.evaluate(jobs, "rank")
    outcomes, weights = evaluator.sample_outcomes(jobs, 30_000, rng)
    mc = evaluator.expected_sojourn_static(
        jobs, policies.rank_order(jobs), outcomes, weights
    )
    assert mc == pytest.approx(exact, rel=0.05)


def test_no_success_contributes_zero():
    # A workload where all jobs always fail at stage 1 -> E = 0.
    jobs = [
        JobSpec(sizes=[1.0, 2.0], probs=[1.0 - 1e-12, 1e-12], job_id=i)
        for i in range(3)
    ]
    val = evaluator.expected_sojourn_static(jobs, np.arange(3))
    assert val == pytest.approx(0.0, abs=1e-6)
