"""Per-architecture smoke tests (deliverable f).

Every assigned arch instantiates its REDUCED config and runs one forward
+ one real train step on CPU, asserting output shapes and no NaNs.  The
FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, get_smoke, list_archs
from repro.launch.train import default_plan, make_init, make_train_step
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingCtx

ARCHS = list_archs()


def _batch(cfg: ModelConfig, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            0.02 * rng.standard_normal((b, cfg.frontend_frames, cfg.d_model)), cfg.dtype
        )
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            0.02 * rng.standard_normal((b, cfg.num_image_tokens, cfg.d_model)), cfg.dtype
        )
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected


def test_kimi_is_a_trillion_param_32b_active():
    cfg = get_config("kimi-k2-1t-a32b")
    assert 0.9e12 < cfg.param_count() < 1.3e12
    assert 25e9 < cfg.param_count(active_only=True) < 40e9


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ctx = ShardingCtx.none()
    batch = _batch(cfg)
    x, aux, _ = T.forward(params, batch, cfg, ctx)
    assert x.shape == (2, 16, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(x.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    plan = default_plan(cfg)
    params, state = make_init(plan)(jax.random.PRNGKey(0))
    step = make_train_step(plan)
    batch = _batch(cfg)
    params, state, metrics = step(params, state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(state.step) == 1
    # one more step must strictly change parameters
    p0 = jax.tree.leaves(params)[0].copy()
    params, state, metrics2 = step(params, state, _batch(cfg, seed=1))
    assert np.isfinite(float(metrics2["loss"]))
    assert not bool(jnp.all(jax.tree.leaves(params)[0] == p0))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-1.3b", "jamba-v0.1-52b",
                                  "mixtral-8x22b", "seamless-m4t-large-v2",
                                  "llama-3.2-vision-11b"])
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ctx = ShardingCtx.none()
    b, s = 2, 8
    batch = _batch(cfg, b, s)
    from repro.models.layers import unembed

    x, _, _ = T.forward(params, batch, cfg, ctx)
    full = unembed(params["embed"], x, cfg, ctx)
    cache = T.init_cache(cfg, b, s)
    memory = (
        T.prime_memory(params, cfg, ctx, batch)
        if cfg.family in ("encdec", "vlm")
        else None
    )
    for t in range(s):
        lg, cache = T.decode_step(
            params, batch["tokens"][:, t : t + 1], cache, jnp.int32(t), cfg, ctx,
            memory=memory,
        )
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 2e-2, (t, err)  # bf16 state-accumulation tolerance
