"""Dry-run tooling tests: collective-bytes HLO parser, roofline terms,
per-device cost_analysis semantics, and a miniature end-to-end dry-run on
an 8-device host mesh (the 512-device campaign runs via launch/dryrun.py)."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.roofline import (
    HW, RooflineReport, collective_bytes, model_flops, roofline_terms,
)


def test_collective_parser_on_synthetic_hlo():
    hlo = textwrap.dedent("""
        %ag = bf16[2,1024,512]{2,1,0} all-gather(bf16[1,1024,512] %x), dim=0
        %ar = f32[128,256]{1,0} all-reduce(f32[128,256] %y), to_apply=%sum
        %rs = f32[64]{0} reduce-scatter(f32[512] %z), dimensions={0}
        %a2a = bf16[16,32]{1,0} all-to-all(bf16[16,32] %w), dimensions={0}
        %cp = u8[100]{0} collective-permute(u8[100] %v), channel_id=1
        %ars = f32[128]{0} all-reduce-start(f32[128] %q)
        %ard = f32[128]{0} all-reduce-done(f32[128] %ars)
        %fused = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce(%a, %b), to_apply=%sum
    """)
    got = collective_bytes(hlo)
    assert got["all-gather"] == 2 * 1024 * 512 * 2
    # plain + async-start + tuple variant (two f32[8,8])
    assert got["all-reduce"] == 128 * 256 * 4 + 128 * 4 + 2 * 8 * 8 * 4
    assert got["reduce-scatter"] == 64 * 4
    assert got["all-to-all"] == 16 * 32 * 2
    assert got["collective-permute"] == 100


def test_roofline_terms_dominance():
    r = roofline_terms(
        flops_per_chip=197e12,  # exactly 1 second of compute
        bytes_per_chip=819e9 / 2,  # 0.5 s of HBM
        coll_bytes={"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
                    "all-to-all": 0, "collective-permute": 0},
    )
    assert r["dominant"] == "compute"
    assert r["compute"] == pytest.approx(1.0)
    assert r["memory"] == pytest.approx(0.5)
    assert r["roofline_fraction"] == pytest.approx(1.0)
    r2 = roofline_terms(1e12, 1e9, {"all-reduce": 50e9})
    # ring all-reduce counts 2x wire bytes
    assert r2["collective"] == pytest.approx(2 * 50e9 / HW.link_bw)
    assert r2["dominant"] == "collective"


def test_model_flops_moe_uses_active_params():
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES

    cfg = get_config("kimi-k2-1t-a32b")
    f = model_flops(cfg, SHAPES["train_4k"], "train")
    toks = 256 * 4096
    assert f == pytest.approx(6.0 * cfg.param_count(active_only=True) * toks)
    assert f < 6.0 * cfg.param_count() * toks / 10  # active << total


_SUBPROCESS_COST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
    M, K, N = 256, 512, 1024
    sh_a = NamedSharding(mesh, P("d", None))
    sh_b = NamedSharding(mesh, P(None, None))

    @jax.jit
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    comp = jax.jit(f, in_shardings=(sh_a, sh_b)).lower(a, b).compile()
    flops = comp.cost_analysis()["flops"]
    total = 2 * M * K * N
    ratio = flops / total
    print("RATIO", ratio)
    # per-device: batch-sharded matmul does total/8 per chip
    assert abs(ratio - 1/8) < 0.02, ratio
""")


def test_cost_analysis_is_per_device():
    """Pins the jax-version-specific semantics the roofline relies on."""
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_COST],
        env=dict(os.environ, PYTHONPATH="src"), capture_output=True, text=True,
        timeout=300,
    )
    assert res.returncode == 0, res.stdout + res.stderr


_SUBPROCESS_MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs.registry import get_smoke
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import default_plan, make_train_step
    from repro.launch.roofline import collective_bytes
    from repro.models import transformer as T
    from repro.optim import adamw as opt

    mesh = make_host_mesh(4, 2)
    cfg = get_smoke("qwen3-1.7b")
    plan = default_plan(cfg, mesh)
    step = make_train_step(plan)
    params = T.abstract_params(cfg)
    opt_state = jax.eval_shape(lambda p: opt.adamw_init(p, plan.opt_cfg), params)
    batch = {k: jax.ShapeDtypeStruct((8, 64), np.int32) for k in ("tokens", "labels")}
    lowered = step.lower(params, opt_state, batch)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    assert cost["flops"] > 0
    coll = collective_bytes(compiled.as_text())
    total = sum(coll.values())
    print("COLLECTIVE BYTES", coll)
    assert total > 0, "sharded train step must emit collectives"
""")


def test_mini_dryrun_on_host_mesh():
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_MINI_DRYRUN],
        env=dict(os.environ, PYTHONPATH="src"), capture_output=True, text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stdout + res.stderr


def test_roofline_report_markdown():
    rows = [{
        "arch": "a", "shape": "s", "mesh": "pod16x16",
        "roofline": {"compute": 1e-3, "memory": 2e-3, "collective": 5e-4,
                     "dominant": "memory", "roofline_fraction": 0.5,
                     "step_time_lower_bound": 2e-3,
                     "collective_bytes": {}, "collective_wire_bytes": 0},
        "useful_flops_ratio": 0.8, "hbm_bytes_per_chip": 2**30,
    }]
    md = RooflineReport(rows).to_markdown()
    assert "| a | s | pod16x16 |" in md and "memory" in md
