"""Cluster-manager tests: DES parity, fault tolerance, stragglers, elasticity,
and the end-to-end integration with real (tiny) training jobs."""

import numpy as np
import pytest

from repro.cluster.faults import FaultConfig
from repro.cluster.manager import ClusterManager, TrainingJob
from repro.core.jobs import JobSpec, generate_workload
from repro.core.simulator import simulate


def _workload(n=100, seed=0, servers_window=50.0):
    rng = np.random.default_rng(seed)
    return generate_workload(
        rng, n, num_stages=3, workload_set=1,
        arrivals=np.sort(rng.uniform(0, servers_window, n)),
    )


@pytest.mark.parametrize("policy", ["rank", "serpt", "sr", "fifo"])
def test_manager_matches_des_without_faults(policy):
    spec = _workload()
    tj = [TrainingJob(spec=s) for s in spec]
    res = ClusterManager(tj, 8, policy=policy, rng=np.random.default_rng(1)).run()
    ref = simulate(spec, 8, policy=policy, rng=np.random.default_rng(1))
    assert res.mean_sojourn_successful == pytest.approx(ref.mean_sojourn_successful)
    assert res.n_success == ref.n_success


def test_failures_delay_but_never_lose_jobs():
    spec = _workload(80, seed=2)
    mk = lambda: [TrainingJob(spec=s) for s in spec]
    base = ClusterManager(mk(), 8, rng=np.random.default_rng(3)).run()
    faulty = ClusterManager(
        mk(), 8, rng=np.random.default_rng(3),
        fault_cfg=FaultConfig(mtbf_hours=0.005, restart_overhead=0.2,
                              straggler_prob=0.0),
        nodes_per_server=8,
    ).run()
    assert faulty.n_jobs == base.n_jobs
    assert faulty.restarts > 0
    assert faulty.n_success == base.n_success  # failures never terminate jobs
    assert faulty.mean_sojourn_successful >= base.mean_sojourn_successful


def test_straggler_mitigation_counts_and_bounds():
    spec = _workload(60, seed=4)
    tj = [TrainingJob(spec=s) for s in spec]
    res = ClusterManager(
        tj, 4, rng=np.random.default_rng(5),
        fault_cfg=FaultConfig(mtbf_hours=1e9, straggler_prob=0.3,
                              straggler_slowdown=10.0, deadline_factor=2.0),
    ).run()
    assert res.straggler_redispatches > 0
    assert res.n_success > 0


def test_elastic_resize_grow_and_shrink():
    spec = _workload(120, seed=6)
    mk = lambda: [TrainingJob(spec=s) for s in spec]
    small = ClusterManager(mk(), 4, rng=np.random.default_rng(7)).run()
    grown = ClusterManager(
        mk(), 4, rng=np.random.default_rng(7),
        resize_events=[(5.0, 16)],
    ).run()
    assert grown.makespan < small.makespan  # adding servers helps
    shrunk = ClusterManager(
        mk(), 16, rng=np.random.default_rng(7),
        resize_events=[(5.0, 2)],
    ).run()
    assert shrunk.n_success == small.n_success  # drain loses nothing


def test_rank_beats_fifo_on_successful_sojourn():
    spec = _workload(300, seed=8, servers_window=20.0)
    mk = lambda: [TrainingJob(spec=s) for s in spec]
    rank = ClusterManager(mk(), 4, policy="rank", rng=np.random.default_rng(9)).run()
    fifo = ClusterManager(mk(), 4, policy="fifo", rng=np.random.default_rng(9)).run()
    assert rank.mean_sojourn_successful < fifo.mean_sojourn_successful


def test_real_runner_integration():
    """Stages actually execute (here: a metric-gated callback), and a gate
    can terminate a job early regardless of its sampled outcome."""
    spec = JobSpec(sizes=np.array([1.0, 2.0, 3.0]), probs=np.array([0.1, 0.1, 0.8]))
    calls = []

    def runner(job, stage):
        calls.append((job.name, stage))
        terminated = stage == 1  # gate kills at the 2nd checkpoint
        return 0.5, terminated

    tj = [TrainingJob(spec=spec, runner=runner, name=f"j{i}") for i in range(3)]
    res = ClusterManager(tj, 2, rng=np.random.default_rng(0)).run()
    assert res.n_jobs == 3
    assert res.n_success == 0  # every job gated at stage 1 (< last stage 2)
    assert all(stage <= 1 for _, stage in calls)


def test_same_instant_arrivals_batch_drain_tiebreak():
    """Regression: all t=0 arrivals drain as one batch before any dispatch,
    so the first server goes to the lowest-index job (not the lowest job
    id), and exact index ties fall back to job position."""
    sizes = [3.0, 1.0, 2.0, 1.0]  # jobs 1 and 3 tie on SERPT index
    spec = [
        JobSpec(sizes=np.array([s]), probs=np.array([1.0]), job_id=i)
        for i, s in enumerate(sizes)
    ]
    tj = [TrainingJob(spec=s) for s in spec]
    res = ClusterManager(tj, 1, policy="serpt", rng=np.random.default_rng(0)).run()
    # seating order: job1 (size 1), job3 (size 1, tie -> higher position),
    # job2 (size 2), job0 (size 3)
    assert [j.completed for j in tj] == [7.0, 1.0, 4.0, 2.0]
    assert res.n_success == 4
    assert res.mean_sojourn_successful == pytest.approx((7.0 + 1.0 + 4.0 + 2.0) / 4)


def test_server_accounting_invariant_under_faults_and_resize():
    """Property: at every engine event, len(running) + free <= target and
    free >= 0 — no server is leaked or double-freed across FAILURE /
    RESIZE / STAGE_DONE interleavings (including shrink-while-busy)."""
    spec = _workload(80, seed=11)
    tj = [TrainingJob(spec=s) for s in spec]
    events = []

    def observer(engine, now):
        pool = engine.pool
        assert pool.free >= 0, now
        assert len(pool.running) + pool.free <= pool.target, now
        events.append(now)

    res = ClusterManager(
        tj, 8, rng=np.random.default_rng(12),
        fault_cfg=FaultConfig(mtbf_hours=0.004, restart_overhead=0.1,
                              straggler_prob=0.2, straggler_slowdown=5.0,
                              deadline_factor=2.0),
        nodes_per_server=8,
        resize_events=[(2.0, 16), (6.0, 3), (10.0, 10)],
    ).run(observer=observer)
    assert res.restarts > 0  # faults actually interleaved with resizes
    assert len(events) > len(spec)  # observer saw every event
    assert res.n_jobs == len(spec)
    assert not np.isnan(res.mean_sojourn_all)  # every job finished
