"""Cluster-manager tests: DES parity, fault tolerance, stragglers, elasticity,
and the end-to-end integration with real (tiny) training jobs."""

import numpy as np
import pytest

from repro.cluster.faults import FaultConfig
from repro.cluster.manager import ClusterManager, TrainingJob
from repro.core.jobs import JobSpec, generate_workload
from repro.core.simulator import simulate


def _workload(n=100, seed=0, servers_window=50.0):
    rng = np.random.default_rng(seed)
    return generate_workload(
        rng, n, num_stages=3, workload_set=1,
        arrivals=np.sort(rng.uniform(0, servers_window, n)),
    )


@pytest.mark.parametrize("policy", ["rank", "serpt", "sr", "fifo"])
def test_manager_matches_des_without_faults(policy):
    spec = _workload()
    tj = [TrainingJob(spec=s) for s in spec]
    res = ClusterManager(tj, 8, policy=policy, rng=np.random.default_rng(1)).run()
    ref = simulate(spec, 8, policy=policy, rng=np.random.default_rng(1))
    assert res.mean_sojourn_successful == pytest.approx(ref.mean_sojourn_successful)
    assert res.n_success == ref.n_success


def test_failures_delay_but_never_lose_jobs():
    spec = _workload(80, seed=2)
    mk = lambda: [TrainingJob(spec=s) for s in spec]
    base = ClusterManager(mk(), 8, rng=np.random.default_rng(3)).run()
    faulty = ClusterManager(
        mk(), 8, rng=np.random.default_rng(3),
        fault_cfg=FaultConfig(mtbf_hours=0.005, restart_overhead=0.2,
                              straggler_prob=0.0),
        nodes_per_server=8,
    ).run()
    assert faulty.n_jobs == base.n_jobs
    assert faulty.restarts > 0
    assert faulty.n_success == base.n_success  # failures never terminate jobs
    assert faulty.mean_sojourn_successful >= base.mean_sojourn_successful


def test_straggler_mitigation_counts_and_bounds():
    spec = _workload(60, seed=4)
    tj = [TrainingJob(spec=s) for s in spec]
    res = ClusterManager(
        tj, 4, rng=np.random.default_rng(5),
        fault_cfg=FaultConfig(mtbf_hours=1e9, straggler_prob=0.3,
                              straggler_slowdown=10.0, deadline_factor=2.0),
    ).run()
    assert res.straggler_redispatches > 0
    assert res.n_success > 0


def test_elastic_resize_grow_and_shrink():
    spec = _workload(120, seed=6)
    mk = lambda: [TrainingJob(spec=s) for s in spec]
    small = ClusterManager(mk(), 4, rng=np.random.default_rng(7)).run()
    grown = ClusterManager(
        mk(), 4, rng=np.random.default_rng(7),
        resize_events=[(5.0, 16)],
    ).run()
    assert grown.makespan < small.makespan  # adding servers helps
    shrunk = ClusterManager(
        mk(), 16, rng=np.random.default_rng(7),
        resize_events=[(5.0, 2)],
    ).run()
    assert shrunk.n_success == small.n_success  # drain loses nothing


def test_rank_beats_fifo_on_successful_sojourn():
    spec = _workload(300, seed=8, servers_window=20.0)
    mk = lambda: [TrainingJob(spec=s) for s in spec]
    rank = ClusterManager(mk(), 4, policy="rank", rng=np.random.default_rng(9)).run()
    fifo = ClusterManager(mk(), 4, policy="fifo", rng=np.random.default_rng(9)).run()
    assert rank.mean_sojourn_successful < fifo.mean_sojourn_successful


def test_real_runner_integration():
    """Stages actually execute (here: a metric-gated callback), and a gate
    can terminate a job early regardless of its sampled outcome."""
    spec = JobSpec(sizes=np.array([1.0, 2.0, 3.0]), probs=np.array([0.1, 0.1, 0.8]))
    calls = []

    def runner(job, stage):
        calls.append((job.name, stage))
        terminated = stage == 1  # gate kills at the 2nd checkpoint
        return 0.5, terminated

    tj = [TrainingJob(spec=spec, runner=runner, name=f"j{i}") for i in range(3)]
    res = ClusterManager(tj, 2, rng=np.random.default_rng(0)).run()
    assert res.n_jobs == 3
    assert res.n_success == 0  # every job gated at stage 1 (< last stage 2)
    assert all(stage <= 1 for _, stage in calls)
