"""Distribution-plane tests: rule resolution + multi-device parity.

Multi-device cases run in a subprocess with
``--xla_force_host_platform_device_count=8`` so the rest of the suite
keeps seeing 1 device (per the dry-run contract)."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import DEFAULT_RULES, LONG_CONTEXT_RULES, rules_for


class _FakeMesh:
    def __init__(self, names):
        self.axis_names = names
        self.empty = False


def test_rules_resolution_single_pod():
    mesh = _FakeMesh(("data", "model"))
    assert DEFAULT_RULES.resolve(("embed", "mlp"), mesh) == P("data", "model")
    assert DEFAULT_RULES.resolve(("batch", "seq", None), mesh) == P("data")
    assert DEFAULT_RULES.resolve((None, "q_heads"), mesh) == P(None, "model")


def test_rules_resolution_multi_pod():
    mesh = _FakeMesh(("pod", "data", "model"))
    assert DEFAULT_RULES.resolve(("embed", "mlp"), mesh) == P(("pod", "data"), "model")
    assert DEFAULT_RULES.resolve(("batch",), mesh) == P(("pod", "data"))


def test_rules_drop_duplicate_axis():
    mesh = _FakeMesh(("data", "model"))
    # two dims both wanting "model": second replicates
    spec = DEFAULT_RULES.resolve(("q_heads", "mlp"), mesh)
    assert spec == P("model")


def test_long_context_rules():
    mesh = _FakeMesh(("data", "model"))
    assert LONG_CONTEXT_RULES.resolve(("batch", "kv_seq"), mesh) == P(None, "data")


def test_serving_weight_rules_layout():
    from repro.parallel.sharding import serving_weight_rules

    mesh = _FakeMesh(("data", "model"))
    base = rules_for(None.__class__, decode_batch=True, model_axis=16)
    # baseline decode layout: batch over ("pod","model"), kv_seq over data
    assert base.resolve(("batch", "kv_seq"), mesh) == P("model", "data")
    srv = serving_weight_rules(base)
    # TP-serving: weights embed-replicated; cache batch→data, kv_seq→model
    assert srv.resolve(("embed", "q_heads"), mesh) == P(None, "model")
    assert srv.resolve(("batch", "kv_seq"), mesh) == P("data", "model")


def test_rules_for_small_expert_count():
    from repro.configs.registry import get_config

    mixtral = get_config("mixtral-8x22b")
    r = rules_for(mixtral, model_axis=16)
    mesh = _FakeMesh(("data", "model"))
    # 8 experts < 16 shards: TP inside experts instead of EP
    assert r.resolve(("experts", "embed", "expert_mlp"), mesh) == P(None, "data", "model")
    kimi = get_config("kimi-k2-1t-a32b")
    r2 = rules_for(kimi, model_axis=16)
    assert r2.resolve(("experts", "embed", "expert_mlp"), mesh) == P("model", "data")


_SUBPROCESS_PARITY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import get_smoke
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import default_plan, make_init, make_train_step

    arch = os.environ["TEST_ARCH"]
    cfg = get_smoke(arch)
    batch = {
        "tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
        "labels": jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.zeros((8, cfg.frontend_frames, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros((8, cfg.num_image_tokens, cfg.d_model), cfg.dtype)

    losses = {}
    for name, mesh in [("single", None), ("mesh", make_host_mesh(4, 2))]:
        plan = default_plan(cfg, mesh)
        params, state = make_init(plan)(jax.random.PRNGKey(0))
        step = make_train_step(plan)
        _, _, metrics = step(params, state, batch)
        losses[name] = float(metrics["loss"])
    diff = abs(losses["single"] - losses["mesh"]) / abs(losses["single"])
    print("LOSSES", losses, "rel_diff", diff)
    assert diff < 2e-2, losses
    """
)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mixtral-8x22b", "mamba2-1.3b",
                                  "jamba-v0.1-52b"])
def test_sharded_train_step_matches_single_device(arch):
    """Same smoke config, same batch: (4 data × 2 model) mesh loss must
    match the single-device loss (GSPMD partitioning is semantics-free)."""
    env = dict(os.environ, TEST_ARCH=arch, PYTHONPATH="src")
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PARITY],
        env=env, capture_output=True, text=True, cwd=os.path.dirname(os.path.dirname(__file__)) or ".",
        timeout=900,
    )
    assert res.returncode == 0, res.stdout + res.stderr


_SUBPROCESS_SP_DECODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.models.attention import sp_decode_attention
    from repro.kernels.flash_attention.ref import ref_attention
    from repro.parallel.sharding import ShardingCtx, LONG_CONTEXT_RULES

    mesh = make_host_mesh(4, 2)
    ctx = ShardingCtx(mesh, LONG_CONTEXT_RULES)
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, D = 1, 64, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    for kv_len in (1, 17, 33, 64):
        out = jax.jit(lambda q,k,v: sp_decode_attention(q, k, v, jnp.int32(kv_len), ctx))(q, k, v)
        ref = ref_attention(q, k, v, causal=False, kv_len=jnp.int32(kv_len))
        err = float(jnp.max(jnp.abs(out - ref)))
        print("kv_len", kv_len, "err", err)
        assert err < 1e-5, (kv_len, err)
    """
)


def test_sp_decode_attention_matches_ref():
    """Distributed LSE-combining decode == reference, incl. partial shards."""
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SP_DECODE],
        env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)) or ".", timeout=600,
    )
    assert res.returncode == 0, res.stdout + res.stderr


def test_smoke_sees_one_device():
    # the dry-run contract: only dryrun.py forces 512 host devices
    assert len(jax.devices()) >= 1
    assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")
