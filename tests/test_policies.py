"""Paper Section III-A worked example + policy index correctness."""

import numpy as np
import pytest

from repro.core import evaluator, policies
from repro.core.jobs import JobSpec, generate_workload


@pytest.fixture
def paper_jobs():
    # N=2 example from Section III-A.
    return [
        JobSpec(sizes=[1, 10], probs=[0.25, 0.75], job_id=0),
        JobSpec(sizes=[3, 6], probs=[0.6, 0.4], job_id=1),
    ]


def test_paper_worked_example_indices(paper_jobs):
    # r(1) = min(4, 7.75) = 4,  r(2) = min(5, 4.2) = 4.2  (Eq. 2)
    sr = policies.sr_rank_values(paper_jobs)
    np.testing.assert_allclose(sr, [4.0, 4.2])
    # ERPT(1)=7.75, ERPT(2)=4.2
    np.testing.assert_allclose(policies.erpt_values(paper_jobs), [7.75, 4.2])
    # After job 1 survives stage 1, its SR rank becomes 9 (paper text).
    table = policies.sr_index_table(paper_jobs)
    assert table[0, 1] == pytest.approx(9.0)


def test_paper_worked_example_sojourn(paper_jobs):
    # E_SR = 10, E_SERPT = 9.75, E_OPTIMAL = 9.1 (paper Section III-A)
    assert evaluator.expected_sojourn_dynamic(paper_jobs, "sr") == pytest.approx(10.0, rel=1e-5)
    assert evaluator.expected_sojourn_dynamic(paper_jobs, "serpt") == pytest.approx(9.75, rel=1e-5)
    order, e_opt = evaluator.optimal_order(paper_jobs)
    assert e_opt == pytest.approx(9.1, rel=1e-5)
    assert list(order) == [0, 1]  # both stages of job 1 before job 2
    # RANK achieves the optimum on this instance.
    assert evaluator.evaluate(paper_jobs, "rank") == pytest.approx(9.1, rel=1e-5)


def test_rank_values_eq23(paper_jobs):
    # R(i) = E[size]/p_success
    np.testing.assert_allclose(
        policies.rank_values(paper_jobs), [7.75 / 0.75, 4.2 / 0.4]
    )


def test_rank_order_scale_invariance():
    rng = np.random.default_rng(0)
    jobs = generate_workload(rng, 8, 2, 1)
    scaled = [
        JobSpec(sizes=j.sizes * 13.7, probs=j.probs, job_id=j.job_id) for j in jobs
    ]
    np.testing.assert_array_equal(policies.rank_order(jobs), policies.rank_order(scaled))


def test_conditional_job_consistency():
    j = JobSpec(sizes=[1.0, 2.0, 5.0], probs=[0.3, 0.2, 0.5])
    c = j.conditional(1)
    np.testing.assert_allclose(c.sizes, [1.0, 4.0])
    np.testing.assert_allclose(c.probs, [0.2 / 0.7, 0.5 / 0.7])
    # conditional rank table matches JobSpec.conditional().rank
    table = policies.rank_index_table([j])
    assert table[0, 1] == pytest.approx(c.rank)


def test_fifo_index_is_arrival_order():
    jobs = [
        JobSpec(sizes=[1, 2], probs=[0.5, 0.5], arrival=5.0, job_id=0),
        JobSpec(sizes=[1, 2], probs=[0.5, 0.5], arrival=1.0, job_id=1),
    ]
    t = policies.fifo_index_table(jobs)
    assert t[1, 0] < t[0, 0]


def test_cache_stats_counts_policy_trial_reuse():
    """Observability counters: repeated policy/trial sweeps over the same
    workload hit the workload-keyed cache instead of recomputing."""
    rng = np.random.default_rng(42)
    jobs = generate_workload(rng, 5)
    policies.clear_workload_cache()
    policies.reset_cache_stats()

    policies.index_table(jobs, "sr")  # trial 1: computes (miss)
    policies.index_table(jobs, "sr")  # trial 2: cached (hit)
    policies.index_table(jobs, "sr")  # trial 3: cached (hit)
    stats = policies.cache_stats()
    assert stats["by_kind"]["idx_table:sr"] == {"hits": 2, "misses": 1}

    # equal content in different JobSpec objects also hits
    clones = [
        JobSpec(sizes=j.sizes.copy(), probs=j.probs.copy(), arrival=j.arrival)
        for j in jobs
    ]
    policies.index_table(clones, "sr")
    stats = policies.cache_stats()
    assert stats["by_kind"]["idx_table:sr"] == {"hits": 3, "misses": 1}
    assert stats["hits"] >= 3 and stats["misses"] >= 1
    assert 0.0 < stats["hit_rate"] < 1.0
    assert stats["entries"] >= 1

    # a different policy on the same workload is a distinct kind: miss
    policies.index_table(jobs, "serpt")
    assert policies.cache_stats()["by_kind"]["idx_table:serpt"]["misses"] == 1

    policies.reset_cache_stats()
    assert policies.cache_stats()["hits"] == 0
    assert policies.cache_stats()["misses"] == 0
