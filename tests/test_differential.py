"""Property-based differential tests for the dynamic-policy evaluators.

Random workloads (N <= 6, ragged stage counts, random probabilities —
including zero-probability outcome rows) are pushed through four
independent implementations of exact stage-level policy evaluation:

* the fused streaming kernel path (``sojourn_eval_dynamic``, XLA scan
  and Pallas interpret mode);
* the seed materialized lockstep simulation (``evaluator._dynamic_batch``);
* the dense pure-Python oracle (``ref.ref_sojourn_dynamic``);
* an exhaustive run of the unified DES (``simulate(..., n_servers=W)``)
  over every enumerated outcome combination.

All four must agree on ``mean_sojourn_successful`` to <= 1e-9 relative,
for ``n_servers = 1`` and for the multi-server cases (W in {2, 3}).
Hypothesis is optional tooling (kept out of the runtime dependency set);
the seeded deterministic slice of this suite lives in
``test_dynamic_eval.py`` and always runs.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, assume, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import policies  # noqa: E402
from repro.core.jobs import JobSpec  # noqa: E402
from test_dynamic_eval import (  # noqa: E402
    RTOL,
    _relerr,
    des_exhaustive,
    fused,
    oracle,
    seed_batch,
)


@st.composite
def workloads(draw, max_jobs=6, max_stages=4):
    """Random ragged workload; interior stop probabilities may be zero."""
    n = draw(st.integers(min_value=2, max_value=max_jobs))
    jobs = []
    for i in range(n):
        m = draw(st.integers(min_value=1, max_value=max_stages))
        incs = draw(
            st.lists(
                st.floats(min_value=0.01, max_value=4.0, allow_nan=False),
                min_size=m,
                max_size=m,
            )
        )
        # Random stop-probability weights; the final (success) entry stays
        # positive so conditional indices are well-defined at every stage.
        w = draw(
            st.lists(
                st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
                min_size=m - 1,
                max_size=m - 1,
            )
        )
        w = np.asarray(w + [draw(st.floats(min_value=0.05, max_value=1.0))])
        jobs.append(
            JobSpec(sizes=np.cumsum(incs), probs=w / w.sum(), job_id=i)
        )
    assume(int(np.prod([j.num_stages for j in jobs])) <= 1024)
    return jobs


def _no_index_ties(jobs, policy):
    """The DES breaks same-instant index ties by heap insertion order while
    the lockstep paths break them by job position; exclude exact-tie
    workloads (duplicated jobs etc.) from the DES comparison."""
    table = np.asarray(policies.index_table(jobs, policy))
    finite = table[np.isfinite(table)]
    return len(np.unique(finite)) == len(finite)


@settings(
    max_examples=30,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
@given(jobs=workloads(), policy=st.sampled_from(["sr", "serpt"]))
def test_lockstep_paths_agree(jobs, policy):
    """Kernel (xla + interpret) vs materialized reference vs dense oracle."""
    ref_es, ref_ea = oracle(jobs, policy)
    assert _relerr(seed_batch(jobs, policy), ref_es) < RTOL
    for impl in ("xla", "interpret"):
        es, ea = fused(jobs, policy, impl)
        assert _relerr(es, ref_es) < RTOL, impl
        assert _relerr(ea, ref_ea) < RTOL, impl


@settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
@given(jobs=workloads(max_stages=3), policy=st.sampled_from(["sr", "serpt"]))
def test_event_simulator_agrees(jobs, policy):
    """Exhaustive DES over all outcomes == the fused kernel path."""
    assume(_no_index_ties(jobs, policy))
    ref_es, _ = oracle(jobs, policy)
    assert _relerr(des_exhaustive(jobs, policy), ref_es) < RTOL
    es, _ = fused(jobs, policy, "xla")
    assert _relerr(es, ref_es) < RTOL


@settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
@given(
    jobs=workloads(max_stages=3),
    policy=st.sampled_from(["sr", "serpt"]),
    n_servers=st.sampled_from([2, 3]),
)
def test_multi_server_paths_agree(jobs, policy, n_servers):
    """W-server parity: exact fused evaluator vs dense oracle vs an
    exhaustive run of the unified DES, for n_servers in {2, 3}."""
    assume(_no_index_ties(jobs, policy))
    ref_es, ref_ea = oracle(jobs, policy, n_servers=n_servers)
    assert _relerr(des_exhaustive(jobs, policy, n_servers=n_servers), ref_es) < RTOL
    for impl in ("xla", "interpret"):
        es, ea = fused(jobs, policy, impl, n_servers=n_servers)
        assert _relerr(es, ref_es) < RTOL, impl
        assert _relerr(ea, ref_ea) < RTOL, impl
