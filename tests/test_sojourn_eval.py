"""Parity tests for the fused sojourn evaluator (repro.kernels.sojourn_eval).

Acceptance bar from the paper repro plan: the fused op must match both the
dense oracle (``ref.py``) and the seed materialized path
(``evaluator._static_batch``) to <= 1e-9 *relative* error on paper-style
workloads.  Everything runs on CPU: the Pallas kernels in interpret mode,
the XLA streaming path compiled; both under x64.
"""

import itertools

import jax
import numpy as np
import pytest

from repro.core import evaluator, policies
from repro.core.jobs import JobSpec, generate_workload
from repro.kernels.sojourn_eval import sojourn_eval
from repro.kernels.sojourn_eval.ref import mixed_radix_strides, ref_decode, ref_sojourn

RTOL = 1e-9
IMPLS = ("xla", "interpret")


def _orders(n, rng, p=6):
    perms = np.array(list(itertools.permutations(range(n))), dtype=np.int32)
    take = rng.choice(len(perms), size=min(p, len(perms)), replace=False)
    return perms[take]


def _ref(jobs, orders, outcomes=None, weights=None):
    sizes, probs, num_stages = policies.padded_arrays(jobs)
    with jax.experimental.enable_x64(True):
        es, ea = ref_sojourn(
            np.float64(sizes), np.float64(probs), num_stages, orders,
            outcomes, weights,
        )
    return np.asarray(es), np.asarray(ea)


def _relerr(a, b):
    return np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-300))


# ---------------------------------------------------------------------------
# Decode / enumeration
# ---------------------------------------------------------------------------


def test_mixed_radix_strides_match_meshgrid():
    num_stages = np.array([2, 3, 2, 4])
    k_total = int(np.prod(num_stages))
    grids = np.meshgrid(*[np.arange(m) for m in num_stages], indexing="ij")
    mesh = np.stack([g.reshape(-1) for g in grids], axis=1)
    np.testing.assert_array_equal(ref_decode(num_stages, k_total), mesh)
    strides = mixed_radix_strides(num_stages)
    assert strides.tolist() == [24, 8, 4, 1]


def test_enumerate_outcomes_vectorized_weights_sum_to_one():
    rng = np.random.default_rng(0)
    jobs = generate_workload(rng, 6, num_stages=3)
    outcomes, weights = evaluator.enumerate_outcomes(jobs)
    assert outcomes.shape == (3**6, 6)
    np.testing.assert_allclose(weights.sum(), 1.0, rtol=1e-12)
    # weights really are the product of per-job stop probabilities
    _, probs, _ = policies.padded_arrays(jobs)
    k = 137
    expect = np.prod([probs[i, outcomes[k, i]] for i in range(6)])
    np.testing.assert_allclose(weights[k], expect, rtol=1e-12)


def test_sample_outcomes_vectorized_matches_distribution():
    rng = np.random.default_rng(1)
    jobs = generate_workload(rng, 4, num_stages=3)
    outcomes, weights = evaluator.sample_outcomes(jobs, 200_000, rng)
    assert outcomes.max() < 3 and outcomes.min() >= 0
    np.testing.assert_allclose(weights.sum(), 1.0, rtol=1e-12)
    _, probs, _ = policies.padded_arrays(jobs)
    for i in range(4):
        freq = np.bincount(outcomes[:, i], minlength=3) / len(outcomes)
        np.testing.assert_allclose(freq, probs[i, :3], atol=5e-3)


# ---------------------------------------------------------------------------
# Fused op vs dense oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("n", range(2, 10))
def test_enum_parity_vs_ref(impl, n):
    rng = np.random.default_rng(n)
    jobs = generate_workload(rng, n)  # paper default M=2
    orders = _orders(n, rng)
    es, ea = sojourn_eval_x64(jobs, orders, impl=impl)
    r_es, r_ea = _ref(jobs, orders)
    assert _relerr(es, r_es) < RTOL
    assert _relerr(ea, r_ea) < RTOL


@pytest.mark.parametrize("impl", IMPLS)
def test_enum_parity_ragged_stages(impl):
    """Jobs with different checkpoint counts (padded M axis exercised)."""
    rng = np.random.default_rng(7)
    jobs = [
        JobSpec(sizes=np.array([1.0, 2.5]), probs=np.array([0.3, 0.7])),
        JobSpec(
            sizes=np.array([0.5, 1.0, 4.0, 6.0]),
            probs=np.array([0.1, 0.2, 0.3, 0.4]),
        ),
        JobSpec(sizes=np.array([2.0]), probs=np.array([1.0])),
        JobSpec(
            sizes=np.array([0.2, 0.9, 1.1]), probs=np.array([0.5, 0.25, 0.25])
        ),
    ]
    orders = _orders(4, rng)
    es, ea = sojourn_eval_x64(jobs, orders, impl=impl)
    r_es, r_ea = _ref(jobs, orders)
    assert _relerr(es, r_es) < RTOL
    assert _relerr(ea, r_ea) < RTOL


@pytest.mark.parametrize("impl", IMPLS)
def test_single_order_matches_batched(impl):
    rng = np.random.default_rng(3)
    jobs = generate_workload(rng, 5, num_stages=3)
    orders = _orders(5, rng)
    batched = evaluator.expected_sojourn_static(jobs, orders, impl=impl)
    for i, order in enumerate(orders):
        single = evaluator.expected_sojourn_static(jobs, order, impl=impl)
        assert isinstance(single, float)
        np.testing.assert_allclose(single, batched[i], rtol=RTOL)


@pytest.mark.parametrize("impl", IMPLS)
def test_outcomes_mode_parity(impl):
    """Explicit outcome tables (MC samples) through the fused op."""
    rng = np.random.default_rng(5)
    jobs = generate_workload(rng, 6, num_stages=3)
    orders = _orders(6, rng)
    outcomes, weights = evaluator.sample_outcomes(jobs, 3000, rng)
    es, ea = sojourn_eval_x64(jobs, orders, outcomes=outcomes, weights=weights, impl=impl)
    r_es, r_ea = _ref(jobs, orders, outcomes, weights)
    assert _relerr(es, r_es) < RTOL
    assert _relerr(ea, r_ea) < RTOL


# ---------------------------------------------------------------------------
# Edge cases (interpret mode so the Pallas kernels run in CI)
# ---------------------------------------------------------------------------


def test_enum_parity_partial_tail_tile():
    """K = 3^7 = 2187: two full (8x128) combination tiles plus a ragged
    tail that must be weight-masked, not evaluated."""
    rng = np.random.default_rng(23)
    jobs = generate_workload(rng, 7, num_stages=3)
    orders = _orders(7, rng, p=3)
    es, ea = sojourn_eval_x64(jobs, orders, impl="interpret")
    r_es, r_ea = _ref(jobs, orders)
    assert _relerr(es, r_es) < RTOL
    assert _relerr(ea, r_ea) < RTOL


def test_enum_parity_n1():
    """A single job: the only 'order' is the identity."""
    jobs = [JobSpec(sizes=np.array([1.0, 3.0]), probs=np.array([0.4, 0.6]))]
    orders = np.zeros((1, 1), dtype=np.int32)
    es, ea = sojourn_eval_x64(jobs, orders, impl="interpret")
    r_es, r_ea = _ref(jobs, orders)
    assert _relerr(es, r_es) < RTOL
    # E[sojourn | success] = p_succ * full size
    np.testing.assert_allclose(es[0], 0.6 * 3.0, rtol=RTOL)
    np.testing.assert_allclose(ea[0], 0.4 * 1.0 + 0.6 * 3.0, rtol=RTOL)


def test_enum_parity_single_stage_jobs():
    """Always-successful single-checkpoint jobs: K = 1 combination, every
    job succeeds, and the padded stage axis degenerates to M = 1."""
    jobs = [
        JobSpec(sizes=np.array([2.0]), probs=np.array([1.0])),
        JobSpec(sizes=np.array([0.5]), probs=np.array([1.0])),
        JobSpec(sizes=np.array([1.25]), probs=np.array([1.0])),
    ]
    orders = np.array([[0, 1, 2], [2, 1, 0]], dtype=np.int32)
    es, ea = sojourn_eval_x64(jobs, orders, impl="interpret")
    r_es, r_ea = _ref(jobs, orders)
    assert _relerr(es, r_es) < RTOL
    assert _relerr(ea, r_ea) < RTOL
    # deterministic: mean of the prefix sums
    np.testing.assert_allclose(es[0], np.mean([2.0, 2.5, 3.75]), rtol=RTOL)


@pytest.mark.parametrize("impl", IMPLS)
def test_enum_parity_zero_probability_row(impl):
    """A job that can never stop early (p = 0 at an interior checkpoint):
    combinations selecting that row carry zero weight and must not
    contribute, even though their durations are still decoded."""
    rng = np.random.default_rng(29)
    jobs = [
        JobSpec(sizes=np.array([1.0, 2.0]), probs=np.array([0.0, 1.0])),
        JobSpec(sizes=np.array([0.5, 1.5, 3.0]), probs=np.array([0.2, 0.0, 0.8])),
        JobSpec(sizes=np.array([1.0, 4.0]), probs=np.array([0.3, 0.7])),
    ]
    orders = _orders(3, rng)
    es, ea = sojourn_eval_x64(jobs, orders, impl=impl)
    r_es, r_ea = _ref(jobs, orders)
    assert _relerr(es, r_es) < RTOL
    assert _relerr(ea, r_ea) < RTOL


# ---------------------------------------------------------------------------
# Fused op vs the seed materialized path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(4, 2), (6, 2), (5, 3), (8, 2), (3, 5)])
def test_parity_vs_seed_static_batch(n, m):
    rng = np.random.default_rng(n * 10 + m)
    jobs = generate_workload(rng, n, num_stages=m)
    orders = _orders(n, rng)
    outcomes, weights = evaluator.enumerate_outcomes(jobs)
    durations, success = evaluator._realized_arrays(jobs, outcomes)
    with jax.experimental.enable_x64(True):
        seed_es, seed_ea = evaluator._static_batch(
            np.float64(durations), success, np.float64(weights), orders,
            also_all_jobs=True,
        )
    seed_es, seed_ea = np.asarray(seed_es), np.asarray(seed_ea)
    for impl in IMPLS:
        es, ea = sojourn_eval_x64(jobs, orders, impl=impl)
        assert _relerr(es, seed_es) < RTOL, impl
        assert _relerr(ea, seed_ea) < RTOL, impl


def test_evaluator_static_entry_uses_fused_path():
    rng = np.random.default_rng(11)
    jobs = generate_workload(rng, 7)
    orders = _orders(7, rng)
    vals = evaluator.expected_sojourn_static(jobs, orders)
    r_es, _ = _ref(jobs, orders)
    assert _relerr(np.asarray(vals), r_es) < RTOL


# ---------------------------------------------------------------------------
# Large-K capability (no (K, N) materialization)
# ---------------------------------------------------------------------------


def test_exact_beyond_materialization_cap():
    """K = 2^22 > MAX_MATERIALIZED_COMBOS: enumerate_outcomes refuses but
    the fused static path evaluates exactly, in bounded memory."""
    rng = np.random.default_rng(13)
    jobs = generate_workload(rng, 22)  # 2^22 combinations
    assert evaluator.exact_combination_count(jobs) == 2**22
    assert evaluator.MAX_EXACT_COMBOS >= 2**26
    with pytest.raises(ValueError, match="MAX_MATERIALIZED_COMBOS"):
        evaluator.enumerate_outcomes(jobs)
    order = policies.rank_order(jobs)
    val = evaluator.expected_sojourn_static(jobs, order)
    assert np.isfinite(val) and val > 0
    # cross-check against an independent MC estimate (loose tolerance)
    mc_o, mc_w = evaluator.sample_outcomes(jobs, 20_000, rng)
    mc = evaluator.expected_sojourn_static(jobs, order, outcomes=mc_o, weights=mc_w)
    assert abs(mc - val) / val < 0.05


def test_evaluate_many_tiering():
    """Static policies stay exact past the materialization cap; dynamic
    ones fall back to MC."""
    rng = np.random.default_rng(17)
    jobs = generate_workload(rng, 22)
    res = evaluator.evaluate_many(jobs, ("rank", "sr"), rng, mc_samples=512)
    assert set(res) == {"rank", "sr"}
    exact = evaluator.expected_sojourn_static(jobs, policies.rank_order(jobs))
    np.testing.assert_allclose(res["rank"], exact, rtol=RTOL)


# ---------------------------------------------------------------------------
# Workload-keyed cache
# ---------------------------------------------------------------------------


def test_workload_cache_hits_and_readonly():
    rng = np.random.default_rng(19)
    jobs = generate_workload(rng, 5)
    a = policies.index_table(jobs, "sr")
    b = policies.index_table(jobs, "sr")
    assert a is b  # same workload content -> cached object
    assert not a.flags.writeable
    # equal content in *different* JobSpec objects also hits
    clones = [
        JobSpec(sizes=j.sizes.copy(), probs=j.probs.copy(), arrival=j.arrival)
        for j in jobs
    ]
    assert policies.index_table(clones, "sr") is a
    # different content misses
    other = generate_workload(rng, 5)
    assert policies.index_table(other, "sr") is not a


def sojourn_eval_x64(jobs, orders, outcomes=None, weights=None, impl="xla"):
    sizes, probs, num_stages = policies.padded_arrays(jobs)
    with jax.experimental.enable_x64(True):
        es, ea = sojourn_eval(
            sizes, probs, num_stages, np.asarray(orders, np.int32),
            outcomes=outcomes, weights=weights, impl=impl,
        )
    return np.asarray(es), np.asarray(ea)
