"""Substrate tests: optimizer, schedules, compression, data, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests
from hypothesis import given, settings, strategies as st

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM, TokenFileDataset
from repro.optim.adamw import (
    OptConfig, adafactor_init, adamw_init, apply_updates,
)
from repro.optim.compress import dequantize, ef_compress, quantize
from repro.optim.schedule import cosine_schedule, linear_warmup


def test_adamw_converges_quadratic():
    p = {"w": jnp.full((8,), 5.0)}
    cfg = OptConfig(lr=0.2, weight_decay=0.0)
    st_ = adamw_init(p, cfg)
    for _ in range(300):
        p, st_ = apply_updates(p, jax.tree.map(lambda w: 2 * w, p), st_, cfg)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_adafactor_factored_and_converges():
    p = {"w": jnp.full((256, 256), 2.0), "b": jnp.full((4,), 2.0)}
    cfg = OptConfig(lr=0.1, weight_decay=0.0, kind="adafactor")
    st_ = adafactor_init(p, cfg)
    assert isinstance(st_.nu["w"], tuple)  # factored
    assert not isinstance(st_.nu["b"], tuple)  # too small to factor
    for _ in range(300):
        p, st_ = apply_updates(p, jax.tree.map(lambda w: 2 * w, p), st_, cfg)
    assert float(jnp.abs(p["w"]).max()) < 5e-2


def test_grad_clipping_bounds_update():
    p = {"w": jnp.zeros((4,))}
    cfg = OptConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    st_ = adamw_init(p, cfg)
    huge = {"w": jnp.full((4,), 1e9)}
    p2, _ = apply_updates(p, huge, st_, cfg)
    assert float(jnp.abs(p2["w"]).max()) < 10.0


def test_bf16_moments():
    p = {"w": jnp.zeros((4,), jnp.bfloat16)}
    cfg = OptConfig(moment_dtype="bfloat16")
    st_ = adamw_init(p, cfg)
    assert st_.mu["w"].dtype == jnp.bfloat16


def test_schedules():
    assert float(linear_warmup(0, 100)) == pytest.approx(0.01)
    assert float(linear_warmup(99, 100)) == pytest.approx(1.0)
    s0 = float(cosine_schedule(100, 100, 1000))
    s1 = float(cosine_schedule(1000, 100, 1000))
    assert s0 == pytest.approx(1.0, abs=1e-2)
    assert s1 == pytest.approx(0.1, abs=1e-2)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(128) * 10 ** rng.uniform(-3, 3), jnp.float32)
    q, scale = quantize(x)
    err = jnp.abs(dequantize(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-9


def test_error_feedback_is_unbiased_over_time():
    """Sum of EF-compressed grads converges to sum of true grads."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros(64)
    total_q = jnp.zeros(64)
    total_g = jnp.zeros(64)
    for _ in range(200):
        g = jnp.asarray(rng.standard_normal(64), jnp.float32)
        q, scale, residual = ef_compress(g, residual)
        total_q = total_q + dequantize(q, scale)
        total_g = total_g + g
    # residual carries the outstanding error; totals differ by <= residual
    np.testing.assert_allclose(
        np.asarray(total_q + residual), np.asarray(total_g), atol=1e-3
    )


def test_synthetic_data_deterministic_and_learnable_structure():
    cfg = DataConfig(vocab_size=977, seq_len=64, global_batch=4, seed=3)
    ds = SyntheticLM(cfg)
    b1, b2 = ds.batch(7), ds.batch(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(8)["tokens"], b1["tokens"])
    # labels are next-token shifted with a trailing pad
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert np.all(b1["labels"][:, -1] == cfg.pad_id)
    # structure: same context hash -> same next token (markov determinism)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 977


def test_token_file_dataset(tmp_path):
    path = os.path.join(tmp_path, "tokens.bin")
    np.arange(10_000, dtype=np.int32).tofile(path)
    cfg = DataConfig(vocab_size=10_000, seq_len=32, global_batch=4, seed=0)
    ds = TokenFileDataset(path, cfg)
    b = ds.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert np.array_equal(b["labels"], b["tokens"] + 1)  # sequential file


def test_checkpoint_roundtrip_gc_and_restore():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        tree = {
            "params": {"w": jnp.arange(12.0).reshape(3, 4).astype(jnp.bfloat16)},
            "step": jnp.int32(7),
        }
        for s in (10, 20, 30):
            cm.save(s, tree)
        cm.wait()
        assert cm.all_steps() == [20, 30]
        assert cm.latest_step() == 30
        target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        back = cm.restore(30, target)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert bool(jnp.all(a == b))


def test_checkpoint_atomicity_no_tmp_left():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=3)
        cm.save(1, {"x": jnp.ones(3)}, blocking=True)
        assert not [f for f in os.listdir(d) if ".tmp" in f]


def test_trainer_checkpoint_restart_resumes():
    """Kill-and-restart continuity: trainer resumes from the saved step."""
    from repro.configs.registry import get_smoke
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.train import Trainer, default_plan

    cfg = get_smoke("qwen3-1.7b")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        plan = default_plan(cfg)
        t1 = Trainer(plan, data, cm, ckpt_every=5)
        _, _, hist1 = t1.run(6, log_every=0)
        # "crash": new trainer, same dir -> resumes at step 6
        t2 = Trainer(plan, data, cm, ckpt_every=5)
        params, state, start = t2.restore_or_init()
        assert start == 6
        _, _, hist2 = t2.run(2, log_every=0)
        assert np.isfinite(hist2).all()
