"""Theorem III.1 / III.2 and Lemma III.3 numerical validation."""

import itertools

import numpy as np
import pytest

from repro.core import evaluator, theory
from repro.core.jobs import generate_workload


def test_poisson_binomial_is_distribution():
    rng = np.random.default_rng(0)
    p = rng.uniform(0, 1, size=12)
    pmf = theory.poisson_binomial(p)
    assert pmf.shape == (13,)
    assert pmf.sum() == pytest.approx(1.0)
    assert np.all(pmf >= 0)


def test_alpha_converges_to_one():
    """Lemma III.3: alpha_{i,j}(N) -> 1 for i.i.d. success probs, beta > 1."""
    rng = np.random.default_rng(1)
    last = 0.0
    for n in (10, 50, 200, 800):
        jobs = generate_workload(rng, n, 2, 1)  # uniform success probs
        a = theory.alpha_ij(jobs, 0, 1)
        assert a > last - 0.02  # monotone-ish growth towards 1
        last = a
    assert last > 0.99


def test_alpha_independent_of_pair_asymptotically():
    rng = np.random.default_rng(2)
    jobs = generate_workload(rng, 300, 2, 1)
    alphas = [theory.alpha_ij(jobs, i, j) for i, j in [(0, 1), (5, 9), (100, 200)]]
    assert max(alphas) - min(alphas) < 0.01


def test_theorem_iii2_exchange_sign():
    """Sign of E[..i,j..] - E[..j,i..] matches R^N_{i,j} comparison."""
    rng = np.random.default_rng(3)
    agree = 0
    trials = 40
    for _ in range(trials):
        jobs = generate_workload(rng, 5, 2, 1)
        o1 = np.array([0, 1, 2, 3, 4])
        o2 = np.array([0, 1, 3, 2, 4])  # swap adjacent positions 2,3
        e1 = evaluator.expected_sojourn_static(jobs, o1)
        e2 = evaluator.expected_sojourn_static(jobs, o2)
        r_i = theory.r_n(jobs, 2, 3, 2)
        r_j = theory.r_n(jobs, 2, 3, 3)
        if abs(e1 - e2) < 1e-9:
            agree += 1
        else:
            agree += int((e1 < e2) == (r_i < r_j))
    assert agree == trials


def test_theorem_iii1_no_preemption_optimal():
    """Brute force: the best stage-interleaved schedule never beats the
    best non-preemptive one (N=3, 2 stages) — Theorem III.1."""
    rng = np.random.default_rng(4)
    for _ in range(5):
        jobs = generate_workload(rng, 3, 2, 1)
        _, best_np = evaluator.optimal_order(jobs)

        # enumerate ALL stage-level schedules as priority strings: a schedule
        # is a sequence over job ids where job i appears M_i times and the
        # k-th occurrence is its k-th stage (legal preemptive schedules).
        stages = [i for i in range(3) for _ in range(2)]
        best_pre = np.inf
        seen = set()
        for perm in itertools.permutations(stages):
            if perm in seen:
                continue
            seen.add(perm)
            val = _eval_stage_schedule(jobs, perm)
            best_pre = min(best_pre, val)
        # Non-preemptive optimum attains the preemptive optimum.
        assert best_np == pytest.approx(best_pre, rel=1e-6)


def _eval_stage_schedule(jobs, stage_seq):
    """Exact E[sojourn of successful] for a fixed stage-interleaving."""
    total = 0.0
    for combo in itertools.product(*[range(j.num_stages) for j in jobs]):
        w = np.prod([jobs[i].probs[c] for i, c in enumerate(combo)])
        t = 0.0
        done = {}
        prog = dict.fromkeys(range(len(jobs)), 0)
        for i in stage_seq:
            if i in done:
                continue
            s = prog[i]
            t += jobs[i].sizes[s] - (jobs[i].sizes[s - 1] if s else 0.0)
            prog[i] += 1
            if s == combo[i]:
                done[i] = t
        succ = [i for i, c in enumerate(combo) if c == jobs[i].num_stages - 1]
        if succ:
            total += w * np.mean([done[i] for i in succ])
    return total


def test_beta_uniform():
    # For p ~ U(eps, 1-eps), beta = E[p/(1-p)] is finite and > 1.
    rng = np.random.default_rng(5)
    p = rng.uniform(1e-5, 1 - 1e-5, size=200_000)
    b = theory.beta_of(p)
    assert 1.0 < b < np.inf


def test_theorem_iii2_exchange_sign_multistage():
    """Exchange criterion holds with heterogeneous stage counts (property
    sweep over M_i in 2..4, random positions)."""
    rng = np.random.default_rng(6)
    trials = 30
    agree = 0
    for _ in range(trials):
        m = int(rng.integers(2, 5))
        jobs = generate_workload(rng, 5, m, int(rng.integers(1, 6)))
        pos = int(rng.integers(0, 4))
        order = np.arange(5)
        swapped = order.copy()
        swapped[pos], swapped[pos + 1] = swapped[pos + 1], swapped[pos]
        i, j = int(order[pos]), int(order[pos + 1])
        e1 = evaluator.expected_sojourn_static(jobs, order)
        e2 = evaluator.expected_sojourn_static(jobs, swapped)
        r_i = theory.r_n(jobs, i, j, i)
        r_j = theory.r_n(jobs, i, j, j)
        if abs(e1 - e2) < 1e-9:
            agree += 1
        else:
            agree += int((e1 < e2) == (r_i < r_j))
    assert agree == trials


def test_rank_matches_optimal_at_moderate_n():
    """Theorem III.4 (asymptotic optimality): at N=8 the RANK order's
    value is within 0.5% of exhaustive OPTIMAL on every tried instance."""
    from repro.core import policies

    rng = np.random.default_rng(7)
    for _ in range(10):
        jobs = generate_workload(rng, 8, 2, 1)
        _, opt = evaluator.optimal_order(jobs)
        val = evaluator.expected_sojourn_static(jobs, policies.rank_order(jobs))
        assert val <= opt * 1.005
