"""Kernel allclose sweeps vs the pure-jnp oracles + hypothesis properties.

All Pallas kernels run in ``interpret=True`` on CPU (the TPU target is
exercised structurally: same BlockSpecs, same grid)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import ref_attention
from repro.kernels.moe_gemm.ops import moe_ffn
from repro.kernels.moe_gemm.ref import moe_ffn_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_chunked, ssd_decode_step, ssd_quadratic


def _rand(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(scale * rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, sq, hq, hkv, d, causal, window, dtype)
    (2, 256, 4, 2, 64, True, None, jnp.float32),
    (1, 256, 4, 4, 128, False, None, jnp.float32),
    (2, 512, 8, 2, 64, True, 128, jnp.float32),
    (1, 128, 2, 1, 64, True, 64, jnp.float32),
    (1, 256, 4, 2, 64, True, None, jnp.bfloat16),
    (2, 384, 6, 2, 64, True, 256, jnp.float32),  # non-pow2 seq (3 blocks)
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_fwd(case):
    b, s, hq, hkv, d, causal, window, dtype = case
    rng = np.random.default_rng(0)
    q = _rand(rng, (b, s, hq, d), dtype)
    k = _rand(rng, (b, s, hkv, d), dtype)
    v = _rand(rng, (b, s, hkv, d), dtype)
    o_ref = ref_attention(q, k, v, causal=causal, window=window)
    o_pal = flash_attention(q, k, v, causal=causal, window=window, impl="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("case", FLASH_CASES[:4])
def test_flash_attention_bwd(case):
    b, s, hq, hkv, d, causal, window, _ = case
    rng = np.random.default_rng(1)
    q = _rand(rng, (b, s, hq, d))
    k = _rand(rng, (b, s, hkv, d))
    v = _rand(rng, (b, s, hkv, d))

    def f(impl):
        return lambda q, k, v: (
            flash_attention(q, k, v, causal=causal, window=window, impl=impl) ** 2
        ).sum()

    g_ref = jax.grad(f("xla"), argnums=(0, 1, 2))(q, k, v)
    g_pal = jax.grad(f("interpret"), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_ref, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-4, rtol=1e-3)


def test_flash_attention_is_causal():
    """Output at position t must not depend on tokens after t."""
    rng = np.random.default_rng(2)
    b, s, h, d = 1, 256, 2, 64
    q, k, v = (_rand(rng, (b, s, h, d)) for _ in range(3))
    o1 = flash_attention(q, k, v, causal=True, impl="interpret")
    k2 = k.at[:, s // 2 :].set(99.0)
    v2 = v.at[:, s // 2 :].set(-99.0)
    o2 = flash_attention(q, k2, v2, causal=True, impl="interpret")
    np.testing.assert_allclose(
        np.asarray(o1[:, : s // 2]), np.asarray(o2[:, : s // 2]), atol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(
    s=st.sampled_from([128, 256]),
    hq=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
    window=st.sampled_from([None, 64]),
)
def test_flash_attention_property(s, hq, g, window):
    rng = np.random.default_rng(abs(hash((s, hq, g, window))) % 2**32)
    hkv = max(hq // g, 1)
    q = _rand(rng, (1, s, hq, 64))
    k = _rand(rng, (1, s, hkv, 64))
    v = _rand(rng, (1, s, hkv, 64))
    o_ref = ref_attention(q, k, v, causal=True, window=window)
    o_pal = flash_attention(q, k, v, causal=True, window=window, impl="interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref), atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, s, h, p, g, n, chunk)
    (2, 256, 4, 64, 2, 32, 64),
    (1, 128, 2, 32, 1, 16, 32),
    (1, 512, 8, 64, 1, 64, 128),
    (2, 64, 4, 16, 4, 8, 16),
]


def _ssd_inputs(rng, b, s, h, p, g, n):
    x = _rand(rng, (b, s, h, p))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = _rand(rng, (b, s, g, n))
    Cm = _rand(rng, (b, s, g, n))
    D = _rand(rng, (h,))
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_vs_quadratic_oracle(case):
    b, s, h, p, g, n, chunk = case
    rng = np.random.default_rng(3)
    args = _ssd_inputs(rng, b, s, h, p, g, n)
    yq, stq = ssd_quadratic(*args)
    yc, stc = ssd_chunked(*args, chunk=chunk)
    yp, stp = ssd_scan(*args, chunk=chunk, impl="interpret")
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yq), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yq), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(stp), np.asarray(stq), atol=1e-4, rtol=1e-4)


def test_ssd_state_chaining_equals_full():
    """Sequence-parallel correctness: scan(A;B) == scan(A) then scan(B|state)."""
    rng = np.random.default_rng(4)
    x, dt, A, Bm, Cm, D = _ssd_inputs(rng, 2, 256, 4, 32, 1, 16)
    y_full, st_full = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=64)
    h = 128
    yA, stA = ssd_chunked(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], D, chunk=64)
    yB, stB = ssd_chunked(
        x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:], D, init_state=stA, chunk=64
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([yA, yB], 1)), np.asarray(y_full), atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(stB), np.asarray(st_full), atol=1e-5)


def test_ssd_decode_step_matches_scan():
    rng = np.random.default_rng(5)
    b, s, h, p, g, n = 2, 16, 4, 32, 1, 16
    x, dt, A, Bm, Cm, D = _ssd_inputs(rng, b, s, h, p, g, n)
    y_ref, st_ref = ssd_quadratic(x, dt, A, Bm, Cm, D)
    st = jnp.zeros((b, h, n, p))
    for t in range(s):
        yt, st = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, st)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(y_ref[:, -1]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([64, 128]),
    chunk=st.sampled_from([16, 32, 64]),
    h=st.sampled_from([2, 4]),
)
def test_ssd_chunk_invariance(s, chunk, h):
    """Output must be independent of the chunking."""
    rng = np.random.default_rng(abs(hash((s, chunk, h))) % 2**32)
    args = _ssd_inputs(rng, 1, s, h, 16, 1, 8)
    y1, st1 = ssd_chunked(*args, chunk=chunk)
    y2, st2 = ssd_chunked(*args, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=1e-4, rtol=1e-4)


def test_ssd_bwd_matches_chunked_ad():
    rng = np.random.default_rng(6)
    args = _ssd_inputs(rng, 1, 128, 2, 32, 1, 16)

    def f_pal(*a):
        return (ssd_scan(*a, chunk=32, impl="interpret")[0] ** 2).sum()

    def f_ref(*a):
        return (ssd_chunked(*a, chunk=32)[0] ** 2).sum()

    g1 = jax.grad(f_pal, argnums=(0, 1, 3, 4))(*args)
    g2 = jax.grad(f_ref, argnums=(0, 1, 3, 4))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# MoE grouped GEMM
# ---------------------------------------------------------------------------

MOE_CASES = [
    (4, 256, 128, 512),
    (8, 128, 64, 256),
    (2, 512, 256, 128),
    (16, 64, 128, 128),
]


@pytest.mark.parametrize("case", MOE_CASES)
def test_moe_ffn_fwd(case):
    e, c, dm, df = case
    rng = np.random.default_rng(7)
    x = _rand(rng, (e, c, dm), scale=0.1)
    wg = _rand(rng, (e, dm, df), scale=0.05)
    wu = _rand(rng, (e, dm, df), scale=0.05)
    wd = _rand(rng, (e, df, dm), scale=0.05)
    o_ref = moe_ffn_ref(x, wg, wu, wd)
    o_pal = moe_ffn(x, wg, wu, wd, impl="interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref), atol=1e-5, rtol=1e-4)


def test_moe_ffn_bwd():
    rng = np.random.default_rng(8)
    e, c, dm, df = 4, 128, 64, 256
    x = _rand(rng, (e, c, dm), scale=0.1)
    wg = _rand(rng, (e, dm, df), scale=0.05)
    wu = _rand(rng, (e, dm, df), scale=0.05)
    wd = _rand(rng, (e, df, dm), scale=0.05)
    g1 = jax.grad(lambda *a: (moe_ffn(*a, impl="interpret") ** 2).sum(), argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    g2 = jax.grad(lambda *a: (moe_ffn_ref(*a) ** 2).sum(), argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-5)


def test_moe_router_no_drops_is_exact():
    """With generous capacity, einsum-dispatched MoE == dense per-token mix."""
    from repro.models.config import ModelConfig
    from repro.models.moe import moe_apply, moe_specs
    from repro.models.init import materialize
    from repro.parallel.sharding import ShardingCtx

    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
        d_ff=64, vocab_size=64, n_experts=4, top_k=2, capacity_factor=16.0,
        moe_impl="xla", param_dtype="float32", compute_dtype="float32",
    )
    params = materialize(moe_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    x = _rand(rng, (2, 8, 32), scale=0.3)
    out, aux = moe_apply(params, x, cfg, ShardingCtx.none())

    # dense reference: softmax-top2 gates, all experts computed
    logits = x.reshape(-1, 32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ci = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = np.zeros((16, 32), np.float32)
    xt = np.asarray(x.reshape(-1, 32))
    for tkn in range(16):
        for j in range(2):
            e = int(ci[tkn, j])
            h = jax.nn.silu(xt[tkn] @ params["wg"][e]) * (xt[tkn] @ params["wu"][e])
            ref[tkn] += float(gv[tkn, j]) * np.asarray(h @ params["wd"][e])
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)), ref, atol=1e-4, rtol=1e-3)
    assert float(aux) > 0
