"""Streaming Monte-Carlo mode: stream parity, CLT accuracy, CRN sharing.

Three claims are tested, matching the design note in
``docs/streaming_mc.md``:

* **Bitwise replay parity** — the counter-based Threefry stream decoded
  inside the kernels (xla / Pallas-interpret) is bit-identical to the
  NumPy host replay in ``ref.ref_mc_outcomes``: evaluating the streamed
  mode and evaluating the replayed dense table give the same estimate
  up to float summation order.
* **CLT accuracy** — on a small-K control the streamed estimate agrees
  with the exact fused enumeration within 3-sigma CLT bounds (sigma
  estimated from the replayed per-sample values).
* **Common random numbers** — the stream is keyed by *original* job id,
  so every order and every policy evaluated under one seed sees the
  identical outcome sequence; ``evaluate_many`` draws one shared seed
  past the exact cap and is reproducible from the caller's rng state.

Plus the satellite guards: zero-survival clamping in
``policies._conditional_arrays`` and the ``REPRO_CACHE_DIR`` disk memo.
"""

import os

import numpy as np
import pytest

from repro.core import evaluator, policies
from repro.core.jobs import JobSpec, generate_workload
from repro.kernels.sojourn_eval import rng, sojourn_eval
from repro.kernels.sojourn_eval.ref import ref_mc_outcomes

IMPLS = ("xla", "interpret")
SEED = 0x5EED_CAFE
RTOL = 1e-9


def _padded(jobs):
    return policies.padded_arrays(jobs)


# ---------------------------------------------------------------------------
# RNG stream
# ---------------------------------------------------------------------------


def test_threefry_numpy_vs_jax_bitwise():
    import jax.numpy as jnp

    k0, k1 = rng.split_seed(SEED)
    x0 = np.arange(1024, dtype=np.uint32).reshape(8, 128)
    x1 = (x0 * np.uint32(2654435761)) % np.uint32(977)
    a0, a1 = rng.threefry2x32(np, (k0, k1), x0, x1)
    b0, b1 = rng.threefry2x32(
        jnp, (jnp.uint32(k0), jnp.uint32(k1)), jnp.asarray(x0), jnp.asarray(x1)
    )
    np.testing.assert_array_equal(a0, np.asarray(b0))
    np.testing.assert_array_equal(a1, np.asarray(b1))


def test_threefry_matches_jax_prng_family():
    """Our block is the same Threefry-2x32 as jax.random's base PRNG."""
    import jax._src.prng as jax_prng

    k0, k1 = 7, 13
    x0 = np.arange(256, dtype=np.uint32)
    x1 = np.zeros(256, dtype=np.uint32)
    ours0, ours1 = rng.threefry2x32(np, (k0, k1), x0, x1)
    theirs = jax_prng.threefry_2x32(
        np.array([k0, k1], dtype=np.uint32),
        np.concatenate([x0, x1]),
    )
    np.testing.assert_array_equal(ours0, np.asarray(theirs[:256]))
    np.testing.assert_array_equal(ours1, np.asarray(theirs[256:]))


def test_split_seed_range_validation():
    assert rng.split_seed(0) == (0, 0)
    lo, hi = rng.split_seed(rng.MAX_SEED - 1)
    assert lo == 0x7FFFFFFF and hi == 0x7FFFFFFF
    with pytest.raises(ValueError):
        rng.split_seed(-1)
    with pytest.raises(ValueError):
        rng.split_seed(rng.MAX_SEED)


def test_host_outcomes_match_stop_distribution():
    g = np.random.default_rng(3)
    jobs = generate_workload(g, 4, num_stages=3)
    _, probs, num_stages = _padded(jobs)
    outcomes = rng.host_outcomes(SEED, 200_000, probs, num_stages)
    for i in range(4):
        freq = np.bincount(outcomes[:, i], minlength=3) / 200_000
        np.testing.assert_allclose(freq, probs[i, :3], atol=5e-3)


# ---------------------------------------------------------------------------
# Bitwise replay parity: streamed kernels vs dense host replay
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
def test_streamed_static_matches_host_replay(impl):
    g = np.random.default_rng(11)
    jobs = generate_workload(g, 5, num_stages=3)
    sizes, probs, num_stages = _padded(jobs)
    orders = np.stack([np.arange(5), np.argsort(-np.arange(5))]).astype(np.int32)
    n_samples = 2048
    outcomes, weights = ref_mc_outcomes(probs, num_stages, SEED, n_samples)
    import jax

    with jax.experimental.enable_x64(True):
        want = sojourn_eval(
            sizes, probs, num_stages, orders,
            outcomes=outcomes, weights=weights, impl="xla",
        )
        got = sojourn_eval(
            sizes, probs, num_stages, orders,
            samples=(SEED, n_samples), impl=impl,
        )
    # Same outcomes, same weights; only the summation order differs.
    np.testing.assert_allclose(got[0], want[0], rtol=RTOL)
    np.testing.assert_allclose(got[1], want[1], rtol=RTOL)


@pytest.mark.parametrize("impl", IMPLS)
def test_streamed_dynamic_matches_host_replay(impl):
    g = np.random.default_rng(12)
    jobs = generate_workload(g, 4, num_stages=3)
    _, probs, num_stages = _padded(jobs)
    n_samples = 1024
    outcomes, weights = ref_mc_outcomes(probs, num_stages, SEED, n_samples)
    for policy in ("sr", "serpt"):
        want = evaluator.expected_sojourn_dynamic(
            jobs, policy, outcomes=outcomes, weights=weights
        )
        got = evaluator.expected_sojourn_dynamic(
            jobs, policy, samples=(SEED, n_samples), impl=impl
        )
        np.testing.assert_allclose(got, want, rtol=RTOL)


def test_streamed_non_pow2_sample_count_tail_masked():
    """Tail lanes past n_samples must carry zero weight."""
    g = np.random.default_rng(13)
    jobs = generate_workload(g, 4, num_stages=3)
    sizes, probs, num_stages = _padded(jobs)
    order = np.arange(4, dtype=np.int32)[None]
    n_samples = 1000  # not a multiple of any tile shape
    outcomes, weights = ref_mc_outcomes(probs, num_stages, SEED, n_samples)
    import jax

    with jax.experimental.enable_x64(True):
        want = sojourn_eval(
            sizes, probs, num_stages, order,
            outcomes=outcomes, weights=weights, impl="xla",
        )
        for impl in IMPLS:
            got = sojourn_eval(
                sizes, probs, num_stages, order,
                samples=(SEED, n_samples), impl=impl,
            )
            np.testing.assert_allclose(got[0], want[0], rtol=RTOL)


# ---------------------------------------------------------------------------
# CLT accuracy against the exact path (small-K control)
# ---------------------------------------------------------------------------


def test_streamed_static_within_clt_of_exact():
    g = np.random.default_rng(21)
    jobs = generate_workload(g, 6, num_stages=3)  # K = 729, exact is cheap
    sizes, probs, num_stages = _padded(jobs)
    order = policies.rank_order(jobs)
    exact = evaluator.expected_sojourn_static(jobs, order)
    n_samples = 1 << 15
    est = evaluator.expected_sojourn_static(jobs, order, samples=(SEED, n_samples))
    # sigma from the replayed per-sample values (exactly what was streamed)
    outcomes, _ = ref_mc_outcomes(probs, num_stages, SEED, n_samples)
    d = sizes[np.arange(len(jobs))[None, :], outcomes]
    succ = outcomes == num_stages[None, :] - 1
    t = np.cumsum(d[:, order], axis=1)
    cnt = succ.sum(axis=1)
    vals = np.where(
        cnt > 0, (t * succ[:, order]).sum(axis=1) / np.maximum(cnt, 1), 0.0
    )
    sigma = vals.std(ddof=1) / np.sqrt(n_samples)
    assert abs(est - exact) <= 3.0 * sigma + 1e-12
    np.testing.assert_allclose(est, vals.mean(), rtol=RTOL)


def test_streamed_dynamic_within_clt_of_exact():
    g = np.random.default_rng(22)
    jobs = generate_workload(g, 5, num_stages=3)  # K = 243
    _, probs, num_stages = _padded(jobs)
    n_samples = 1 << 15
    for policy in ("sr", "serpt"):
        exact = evaluator.expected_sojourn_dynamic(jobs, policy)
        est = evaluator.expected_sojourn_dynamic(
            jobs, policy, samples=(SEED, n_samples)
        )
        # conservative sigma bound: per-sample values live in [0, sum durs]
        outcomes, weights = ref_mc_outcomes(probs, num_stages, SEED, n_samples)
        mc_table = evaluator.expected_sojourn_dynamic(
            jobs, policy, outcomes=outcomes, weights=weights
        )
        # the streamed estimate IS the table estimate (parity), and the
        # table estimate is an unbiased S-sample MC mean of the exact value
        np.testing.assert_allclose(est, mc_table, rtol=RTOL)
        span = float(policies.stage_durations(jobs).sum())
        sigma = span / np.sqrt(n_samples)  # worst-case bound on std
        assert abs(est - exact) <= 3.0 * sigma


# ---------------------------------------------------------------------------
# Common random numbers
# ---------------------------------------------------------------------------


def test_common_random_numbers_across_orders_and_policies():
    """The stream is keyed by original job id: every order and policy
    under one seed sees the same outcome table."""
    g = np.random.default_rng(31)
    jobs = generate_workload(g, 5, num_stages=3)
    sizes, probs, num_stages = _padded(jobs)
    n_samples = 4096
    outcomes, weights = ref_mc_outcomes(probs, num_stages, SEED, n_samples)
    # two different static orders against the shared replayed table
    for order in (np.arange(5), np.array([4, 2, 0, 3, 1])):
        want = evaluator.expected_sojourn_static(
            jobs, order, outcomes=outcomes, weights=weights
        )
        got = evaluator.expected_sojourn_static(
            jobs, order, samples=(SEED, n_samples)
        )
        np.testing.assert_allclose(got, want, rtol=RTOL)
    # dynamic policies against the same table under the same seed
    for policy in ("sr", "serpt"):
        want = evaluator.expected_sojourn_dynamic(
            jobs, policy, outcomes=outcomes, weights=weights
        )
        got = evaluator.expected_sojourn_dynamic(
            jobs, policy, samples=(SEED, n_samples)
        )
        np.testing.assert_allclose(got, want, rtol=RTOL)


def test_evaluate_many_beyond_cap_streams_one_shared_seed():
    g = np.random.default_rng(41)
    jobs = generate_workload(g, 14, num_stages=4)  # K = 4^14 = 2^28 > cap
    assert evaluator.exact_combination_count(jobs) > evaluator.MAX_EXACT_COMBOS
    res = evaluator.evaluate_many(
        jobs, ("rank", "serpt", "sr"), np.random.default_rng(99), mc_samples=2048
    )
    # reproducible purely from the caller's rng state: one seed, shared
    g2 = np.random.default_rng(99)
    seed = int(g2.integers(0, rng.MAX_SEED))
    for alg in ("rank", "serpt", "sr"):
        want = evaluator.evaluate(jobs, alg, samples=(seed, 2048))
        assert res[alg] == want
    # CRN: same seed means identical outcomes, so the rank-vs-serpt gap
    # is measured on common random numbers (no sampling-noise cross-term)
    assert set(res) == {"rank", "serpt", "sr"}
    assert all(np.isfinite(v) and v > 0 for v in res.values())


def test_evaluate_many_within_cap_still_exact():
    g = np.random.default_rng(42)
    jobs = generate_workload(g, 5, num_stages=3)
    r1 = evaluator.evaluate_many(jobs, ("rank", "sr"), np.random.default_rng(1))
    r2 = evaluator.evaluate_many(jobs, ("rank", "sr"), np.random.default_rng(2))
    assert r1 == r2  # exact tier: rng must not influence results


# ---------------------------------------------------------------------------
# Satellite guards
# ---------------------------------------------------------------------------


def test_conditional_arrays_zero_survival_is_finite():
    # All stop mass on stage 0: surviving it has probability 0 and the
    # conditional tables must clamp instead of emitting inf/nan.
    jobs = [
        JobSpec(sizes=[1.0, 2.0, 3.0], probs=[1.0, 0.0, 0.0], job_id=0),
        JobSpec(sizes=[1.0, 4.0], probs=[0.5, 0.5], job_id=1),
    ]
    for table_fn in (policies.serpt_index_table, policies.sr_index_table):
        table = table_fn(jobs)
        assert not np.isnan(table).any()
        assert np.isfinite(table[0, 0])
    # rank_index_table divides by the conditional success probability and
    # may legitimately be +inf for a job that cannot succeed, but nan is
    # a bug in any table.
    assert not np.isnan(policies.rank_index_table(jobs)).any()


def test_conditional_arrays_rounding_survival():
    # Prefix mass sums to exactly 1.0 in float64 while a positive tail
    # remains (legal within JobSpec's 1e-9 tolerance); the clamp
    # renormalizes by the tail mass so the conditional probs stay a
    # distribution instead of dividing by zero.
    jobs = [JobSpec(sizes=[1.0, 2.0, 3.0], probs=[0.5, 0.5, 1e-10], job_id=0)]
    for _, s, _, rem_probs in policies._conditional_arrays(jobs):
        assert np.isfinite(rem_probs).all()
        if s == 2:
            np.testing.assert_allclose(rem_probs.sum(), 1.0, rtol=1e-12)


def test_disk_cache_roundtrip_and_counters(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    g = np.random.default_rng(51)
    jobs = generate_workload(g, 5)
    policies.clear_workload_cache()
    policies.reset_cache_stats()

    t1 = policies.index_table(jobs, "sr")  # mem miss + disk miss: computes
    policies.clear_workload_cache()  # drop memory, keep disk
    t2 = policies.index_table(jobs, "sr")  # mem miss + disk hit: loads
    np.testing.assert_array_equal(t1, t2)
    assert not t2.flags.writeable  # loaded entries are frozen too
    t3 = policies.index_table(jobs, "sr")  # mem hit: disk untouched
    np.testing.assert_array_equal(t1, t3)

    stats = policies.cache_stats()
    assert stats["disk_hits"] == 1 and stats["disk_misses"] == 1
    assert stats["by_kind"]["idx_table:sr"] == {
        "hits": 1, "misses": 2, "disk_hits": 1, "disk_misses": 1,
    }
    files = list(tmp_path.iterdir())
    assert len(files) == 1 and files[0].name.endswith(".npz")
    assert ":" not in files[0].name  # kind is sanitized for filenames


def test_disk_cache_tuple_entries_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    g = np.random.default_rng(52)
    jobs = generate_workload(g, 4, num_stages=3)
    policies.clear_workload_cache()
    k1, strides1, radix1 = evaluator._enum_meta(jobs)
    policies.clear_workload_cache()
    k2, strides2, radix2 = evaluator._enum_meta(jobs)  # from disk
    assert isinstance(k2, int) and k1 == k2 == 3**4
    np.testing.assert_array_equal(strides1, strides2)
    np.testing.assert_array_equal(radix1, radix2)


def test_disk_cache_off_keeps_legacy_stats_shape():
    g = np.random.default_rng(53)
    jobs = generate_workload(g, 4)
    policies.clear_workload_cache()
    policies.reset_cache_stats()
    policies.index_table(jobs, "sr")
    policies.index_table(jobs, "sr")
    stats = policies.cache_stats()
    assert stats["by_kind"]["idx_table:sr"] == {"hits": 1, "misses": 1}
    assert "disk_hits" not in stats


def test_disk_cache_lru_eviction_and_counter(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    g = np.random.default_rng(54)
    w_a, w_b, w_c = (generate_workload(g, 5) for _ in range(3))
    policies.clear_workload_cache()
    policies.reset_cache_stats()

    policies.index_table(w_a, "sr")
    (file_a,) = tmp_path.iterdir()
    entry = file_a.stat().st_size
    # bound fits two entries; the third store must evict the stalest
    monkeypatch.setenv("REPRO_CACHE_DISK_BYTES", str(int(2.5 * entry)))
    policies.index_table(w_b, "sr")
    file_b = next(f for f in tmp_path.iterdir() if f != file_a)
    assert "disk_evictions" not in policies.cache_stats()  # still under bound

    # pin recency: a is fresh, b is stale -> b is the LRU victim
    os.utime(file_a, (1_000, 1_000))
    os.utime(file_b, (500, 500))
    policies.index_table(w_c, "sr")
    names = {f.name for f in tmp_path.iterdir()}
    assert file_a.name in names and file_b.name not in names
    assert len(names) == 2
    assert policies.cache_stats()["disk_evictions"] == 1

    # a disk *hit* refreshes the entry's mtime (loads count as uses)
    policies.clear_workload_cache()
    policies.index_table(w_a, "sr")
    assert file_a.stat().st_mtime > 1_000

    policies.reset_cache_stats()
    assert "disk_evictions" not in policies.cache_stats()


def test_disk_cache_unbounded_opt_out(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_CACHE_DISK_BYTES", "none")
    g = np.random.default_rng(55)
    policies.clear_workload_cache()
    policies.reset_cache_stats()
    for _ in range(4):
        policies.index_table(generate_workload(g, 5), "sr")
    assert len(list(tmp_path.iterdir())) == 4  # nothing evicted
    assert "disk_evictions" not in policies.cache_stats()


def test_ensure_cache_dir_respects_explicit_setting(tmp_path, monkeypatch):
    explicit = tmp_path / "explicit"
    monkeypatch.setenv("REPRO_CACHE_DIR", str(explicit))
    assert policies.ensure_cache_dir() == str(explicit)
    assert explicit.is_dir()
    # unset: falls back to the default location (created on demand)
    monkeypatch.delenv("REPRO_CACHE_DIR")
    monkeypatch.setenv("XDG_CACHE_HOME", str(tmp_path / "xdg"))
    got = policies.ensure_cache_dir()
    assert got == str(tmp_path / "xdg" / "repro-workloads")
    assert os.environ["REPRO_CACHE_DIR"] == got
