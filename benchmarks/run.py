"""Benchmark harness: one function per paper table/figure + roofline.

Paper mapping:
  fig1_objective_gap   -> Figure 1   (all-jobs vs successful-jobs objective)
  table_sojourn        -> Tables IV-VIII / Figures 3-7 (mean sojourn, sets 1-5)
  table_competitive    -> Tables IX-XIII (max/p95/p75 competitive ratios)
  table_stages         -> Table XIV  (stage-count sweep)
  table_trace          -> Tables XVI-XVIII (trace-driven online study)
  table_roofline       -> EXPERIMENTS.md §Roofline (reads dry-run artifacts)

Default is a CI-friendly scale (~minutes on 1 CPU core): fewer trials and
a load-matched subsampled trace; ``--full`` switches to paper scale
(50k trials, 109,967 jobs).  Orderings and relative gaps are the
reproduction target at either scale; absolute numbers carry sampling
error shown as ±stderr.  Results are printed as markdown and written to
artifacts/bench/*.json.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

import numpy as np

from repro.configs.paper_workloads import NUMERICAL, TRACE
from repro.core.evaluator import evaluate_many, exact_combination_count
from repro.core.jobs import generate_workload
from repro.core.simulator import simulate
from repro.core.trace import synthesize_trace

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "bench")


def _save(name: str, obj) -> None:
    """Write artifacts/bench/<name>.json.

    Rows are wrapped as ``{"rows": ..., "workload_cache": cache_stats()}``
    so every artifact records the workload-keyed cache behavior of the
    run that produced it.
    """
    from repro.core import policies

    if not isinstance(obj, dict):
        obj = {"rows": obj}
    obj = {**obj, "workload_cache": policies.cache_stats()}
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1)


def _trials_for(n_jobs: int, full: bool) -> int:
    if full:
        return NUMERICAL.trials
    return {3: 400, 4: 400, 5: 300, 6: 200, 7: 120, 8: 60}.get(n_jobs, 200)


# ---------------------------------------------------------------------------
# Figure 1
# ---------------------------------------------------------------------------


def fig1_objective_gap(full: bool = False):
    """Mean sojourn of successful jobs: optimize-for-all (SR) vs
    optimize-for-successful (RANK), vs number of jobs."""
    rows = []
    rng = np.random.default_rng(42)
    for n in (3, 4, 5, 6, 7, 8, 9, 10):
        trials = _trials_for(min(n, 8), full)
        vals = {"rank": [], "sr": []}
        for _ in range(trials):
            jobs = generate_workload(rng, n, workload_set=1)
            res = evaluate_many(jobs, ("rank", "sr"), rng)
            for k in vals:
                vals[k].append(res[k])
        rows.append({
            "n_jobs": n,
            "optimize_successful(RANK)": float(np.mean(vals["rank"])),
            "optimize_all(SR)": float(np.mean(vals["sr"])),
            "gap_pct": 100 * (np.mean(vals["sr"]) / np.mean(vals["rank"]) - 1),
        })
    _save("fig1", rows)
    return rows


# ---------------------------------------------------------------------------
# Tables IV-VIII (+ Figures 3-7) and IX-XIII
# ---------------------------------------------------------------------------


def _numerical_study(full: bool, sets=None, n_jobs=None):
    """Shared sweep: per (workload set, N): mean sojourn per alg +
    competitive ratios vs OPTIMAL."""
    sets = sets or NUMERICAL.workload_sets
    n_jobs = n_jobs or NUMERICAL.n_jobs_sweep
    algs = ("optimal", "rank", "serpt", "sr", "random")
    out = {}
    rng = np.random.default_rng(7)
    for ws in sets:
        for n in n_jobs:
            trials = _trials_for(n, full)
            vals = {a: np.empty(trials) for a in algs}
            for t in range(trials):
                jobs = generate_workload(rng, n, num_stages=NUMERICAL.num_stages,
                                         workload_set=ws)
                res = evaluate_many(jobs, algs, rng)
                for a in algs:
                    vals[a][t] = res[a]
            cr = {a: vals[a] / vals["optimal"] for a in algs if a != "optimal"}
            out[(ws, n)] = {
                "mean": {a: float(vals[a].mean()) for a in algs},
                "stderr": {a: float(vals[a].std() / np.sqrt(trials)) for a in algs},
                "cr_max": {a: float(v.max()) for a, v in cr.items()},
                "cr_p95": {a: float(np.percentile(v, 95)) for a, v in cr.items()},
                "cr_p75": {a: float(np.percentile(v, 75)) for a, v in cr.items()},
                "trials": trials,
            }
    return out


def table_sojourn(full: bool = False, study=None):
    """Tables IV-VIII: average expected sojourn of successful jobs."""
    study = study or _numerical_study(full)
    rows = []
    for (ws, n), r in sorted(study.items()):
        rows.append({
            "workload_set": ws, "n_jobs": n, "trials": r["trials"],
            **{f"{a}": r["mean"][a] for a in ("optimal", "rank", "serpt", "sr", "random")},
            "rank_vs_optimal_pct": 100 * (r["mean"]["rank"] / r["mean"]["optimal"] - 1),
        })
    _save("table_sojourn", rows)
    return rows


def table_competitive(full: bool = False, study=None):
    """Tables IX-XIII: competitive-ratio max / p95 / p75."""
    study = study or _numerical_study(full)
    rows = []
    for (ws, n), r in sorted(study.items()):
        for metric in ("cr_max", "cr_p95", "cr_p75"):
            rows.append({
                "workload_set": ws, "n_jobs": n, "metric": metric,
                **{a: r[metric][a] for a in ("rank", "serpt", "sr", "random")},
            })
    _save("table_competitive", rows)
    return rows


def table_stages(full: bool = False):
    """Table XIV: stage-count sweep at N=5, uniform set."""
    rows = []
    rng = np.random.default_rng(11)
    n = 5
    for m in NUMERICAL.stages_sweep:
        trials = _trials_for(n, full)
        vals = {"optimal": np.empty(trials), "rank": np.empty(trials)}
        crs = np.empty(trials)
        for t in range(trials):
            jobs = generate_workload(rng, n, num_stages=m, workload_set=1)
            res = evaluate_many(jobs, ("optimal", "rank"), rng)
            vals["optimal"][t] = res["optimal"]
            vals["rank"][t] = res["rank"]
            crs[t] = res["rank"] / res["optimal"]
        rows.append({
            "num_stages": m, "trials": trials,
            "optimal": float(vals["optimal"].mean()),
            "rank": float(vals["rank"].mean()),
            "max_cr": float(crs.max()),
        })
    _save("table_stages", rows)
    return rows


# ---------------------------------------------------------------------------
# Tables XVI-XVIII: trace-driven online study
# ---------------------------------------------------------------------------


def table_trace(full: bool = False):
    rows = []
    n_jobs = TRACE.n_jobs if full else TRACE.n_jobs_fast
    duration = TRACE.duration_days * (n_jobs / TRACE.n_jobs)  # load-matched
    for sp in TRACE.synthetic_success_probs:
        dataset = {None: "philly-synthetic", 0.5: "synthetic-I", 0.25: "synthetic-II"}[sp]
        rng = np.random.default_rng(13)
        jobs = synthesize_trace(rng, n_jobs=n_jobs, duration_days=duration,
                                success_prob=sp)
        for w in TRACE.server_counts:
            row = {"dataset": dataset, "servers": w}
            for pol in TRACE.policies:
                res = simulate(jobs, w, policy=pol, rng=np.random.default_rng(17))
                row[pol] = res.mean_sojourn_successful
                row[f"{pol}_nsucc"] = res.n_success
            row["rank_vs_serpt_pct"] = 100 * (1 - row["rank"] / row["serpt"])
            rows.append(row)
    _save("table_trace", rows)
    return rows


# ---------------------------------------------------------------------------
# Beyond-paper: policy robustness under failures / stragglers / elasticity
# ---------------------------------------------------------------------------


def table_faults(full: bool = False):
    """RANK's advantage must survive the failure modes of a real cluster
    (the paper's model is failure-free).  Same trace-style workload, now
    with node failures (gang restart from checkpoint), straggler
    re-dispatch, and an elastic resize mid-run."""
    from repro.cluster.faults import FaultConfig
    from repro.cluster.manager import ClusterManager, TrainingJob

    n = 2000 if not full else 10000
    servers = 8
    rng = np.random.default_rng(21)
    # offered load ~2x capacity: queueing decisions matter
    arrivals = np.sort(rng.uniform(0, n * 0.75 / (2 * servers), n))
    base_jobs = generate_workload(rng, n, num_stages=3, workload_set=1,
                                  arrivals=arrivals)
    scenarios = {
        "clean": dict(fault_cfg=None),
        "faulty": dict(fault_cfg=FaultConfig(mtbf_hours=0.002, restart_overhead=0.5,
                                             straggler_prob=0.05,
                                             straggler_slowdown=5.0),
                       nodes_per_server=8),
        "elastic": dict(fault_cfg=None,
                        resize_events=[(20.0, 12), (60.0, 4)]),
    }
    rows = []
    for scen, kw in scenarios.items():
        row = {"scenario": scen}
        for pol in ("rank", "serpt", "sr", "fifo"):
            jobs = [TrainingJob(spec=s) for s in base_jobs]
            res = ClusterManager(jobs, servers, policy=pol,
                                 rng=np.random.default_rng(5), **kw).run()
            row[pol] = res.mean_sojourn_successful
            if pol == "rank":
                row["restarts"] = res.restarts
                row["straggler_redisp"] = res.straggler_redispatches
        row["rank_vs_serpt_pct"] = 100 * (1 - row["rank"] / row["serpt"])
        rows.append(row)
    _save("table_faults", rows)
    return rows


# ---------------------------------------------------------------------------
# Fused evaluator microbenchmark (BENCH_eval.json)
# ---------------------------------------------------------------------------


def table_eval_perf(full: bool = False):
    """Seed materialized evaluator vs the fused streaming op.

    The seed path builds the (K, N) outcome/duration/success tables on the
    host and runs the jitted ``_static_batch`` reduction; the fused path
    (``repro.kernels.sojourn_eval``) decodes combinations on the fly and
    never materializes them.  Timed at K = 2**21 (the seed's exact-eval
    cap); ``--full`` adds a fused-only row at K = 2**26, beyond what the
    seed could represent in memory.
    """
    import jax

    from repro.core import evaluator, policies

    def fused_time(jobs, orders, repeats):
        ts = []
        for _ in range(repeats + 1):  # first rep warms the jit cache
            t0 = time.perf_counter()
            vals = evaluator.expected_sojourn_static(jobs, orders, impl="xla")
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts[1:])), np.asarray(vals)

    def seed_time(jobs, orders, repeats):
        ts = []
        for _ in range(repeats + 1):
            t0 = time.perf_counter()
            # per-call work in the seed design: materialize + gather + jit
            outcomes, weights = evaluator.enumerate_outcomes(jobs)
            durations, success = evaluator._realized_arrays(jobs, outcomes)
            with jax.experimental.enable_x64(True):
                vals = np.asarray(evaluator._static_batch(
                    np.float64(durations), success, np.float64(weights),
                    orders,
                ))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts[1:])), vals

    rows = []
    rng = np.random.default_rng(31)
    repeats = 5 if full else 3

    n = 21  # M=2 -> K = 2**21, the seed cap
    jobs = generate_workload(rng, n)
    orders = np.stack([policies.rank_order(jobs),
                       rng.permutation(n).astype(np.int32)])
    t_fused, v_fused = fused_time(jobs, orders, repeats)
    t_seed, v_seed = seed_time(jobs, orders, repeats)
    relerr = float(np.max(np.abs(v_fused - v_seed) / np.abs(v_seed)))
    assert relerr <= 1e-9, f"fused/seed divergence: {relerr}"
    rows.append({
        "k_combos": 1 << n, "n_jobs": n, "orders": len(orders),
        "seed_s": t_seed, "fused_s": t_fused,
        "speedup": t_seed / t_fused, "max_relerr_vs_seed": relerr,
    })

    if full:  # beyond the seed's representable range: fused only
        n = 26
        jobs = generate_workload(rng, n)
        orders = policies.rank_order(jobs)[None]
        t_fused, _ = fused_time(jobs, orders, 1)
        rows.append({
            "k_combos": 1 << n, "n_jobs": n, "orders": 1,
            "seed_s": None, "fused_s": t_fused,
            "speedup": None, "max_relerr_vs_seed": None,
        })

    _save("BENCH_eval", rows)
    return rows


def table_eval_dynamic(full: bool = False):
    """Seed materialized lockstep vs the fused dynamic op (BENCH_eval_dynamic).

    The seed design for SR/SERPT (``evaluator._dynamic_batch``) materializes
    the (K, N) outcome/success tables host-side and simulates every
    combination in a vmapped ``fori_loop``; the fused op
    (``repro.kernels.sojourn_eval.dynamic``) decodes combinations on the
    fly and simulates them inside streaming tiles.  Timed at K = 2**21
    (the seed's materialization cap); ``--full`` adds SERPT and a
    fused-only row at K = 2**26, beyond what the seed could represent.
    """
    import jax

    from repro.core import evaluator, policies

    def fused_time(jobs, policy, repeats):
        ts = []
        for _ in range(repeats + 1):  # first rep warms the jit cache
            t0 = time.perf_counter()
            val = evaluator.expected_sojourn_dynamic(jobs, policy, impl="xla")
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts[1:])), val

    def seed_time(jobs, policy, repeats):
        idx_table = policies.index_table(jobs, policy)
        stage_durs = policies.stage_durations(jobs)
        _, _, num_stages = policies.padded_arrays(jobs)
        ts = []
        for _ in range(repeats + 1):
            t0 = time.perf_counter()
            # per-call work in the seed design: materialize + gather + jit
            outcomes, weights = evaluator.enumerate_outcomes(jobs)
            _, success = evaluator._realized_arrays(jobs, outcomes)
            with jax.experimental.enable_x64(True):
                val = float(evaluator._dynamic_batch(
                    np.float64(idx_table), np.float64(stage_durs), outcomes,
                    success, np.float64(weights), int(num_stages.sum()),
                ))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts[1:])), val

    rows = []
    rng = np.random.default_rng(37)
    repeats = 2 if full else 1
    policies_timed = ("sr", "serpt") if full else ("sr",)

    n = 21  # M=2 -> K = 2**21, the materialization cap
    jobs = generate_workload(rng, n)
    for policy in policies_timed:
        t_fused, v_fused = fused_time(jobs, policy, repeats)
        t_seed, v_seed = seed_time(jobs, policy, repeats)
        relerr = abs(v_fused - v_seed) / abs(v_seed)
        assert relerr <= 1e-9, f"fused/seed divergence: {relerr}"
        rows.append({
            "k_combos": 1 << n, "n_jobs": n, "policy": policy,
            "seed_s": t_seed, "fused_s": t_fused,
            "speedup": t_seed / t_fused, "max_relerr_vs_seed": relerr,
        })

    if full:  # beyond the seed's representable range: fused only
        n = 26
        jobs = generate_workload(rng, n)
        t_fused, _ = fused_time(jobs, "sr", 1)
        rows.append({
            "k_combos": 1 << n, "n_jobs": n, "policy": "sr",
            "seed_s": None, "fused_s": t_fused,
            "speedup": None, "max_relerr_vs_seed": None,
        })

    _save("BENCH_eval_dynamic", {"rows": rows})
    return rows


def table_eval_mc(full: bool = False, smoke: bool = False):
    """Streamed Monte Carlo vs the materialized sample-table path
    (BENCH_eval_mc).

    Beyond ``MAX_EXACT_COMBOS`` the evaluator estimates by Monte Carlo.
    The materialized design (``sample_outcomes`` + the explicit-outcomes
    op) builds the (S, N) sample table host-side every call, so the
    sample count is bounded by host memory and the throughput by table
    traffic; the streamed design (``samples=(seed, n_samples)``)
    generates outcomes inside the evaluation tiles from the Threefry
    counter stream and never materializes them.  Timed on a
    K = 2**27 > MAX_EXACT_COMBOS workload: streamed at 2**23 samples vs
    materialized at its practical 2**21 — the streamed path must be
    >= 2x the throughput at 4x the samples.  A small-K control checks
    the streamed estimate against the exact fused enumeration within
    3-sigma CLT bounds (sigma replayed host-side from the same stream).

    ``smoke`` (CI) shrinks sample counts and runs the Pallas kernels in
    interpret mode instead of the compiled XLA path — a crash/parity
    canary, not a performance measurement.
    """
    from repro.core import evaluator, policies
    from repro.kernels.sojourn_eval.ref import ref_mc_outcomes

    impl = "interpret" if smoke else "xla"
    seed = 0x5EED
    rng = np.random.default_rng(43)

    # --- small-K control: streamed estimate vs exact, CLT bound ----------
    ctrl_samples = 1 << (12 if smoke else 16)
    ctrl_jobs = generate_workload(rng, 8)  # K = 256
    order = policies.rank_order(ctrl_jobs)
    exact = evaluator.expected_sojourn_static(ctrl_jobs, order, impl=impl)
    est = evaluator.expected_sojourn_static(
        ctrl_jobs, order, samples=(seed, ctrl_samples), impl=impl
    )
    sizes, probs, num_stages = policies.padded_arrays(ctrl_jobs)
    outcomes, _ = ref_mc_outcomes(probs, num_stages, seed, ctrl_samples)
    d = sizes[np.arange(len(ctrl_jobs))[None, :], outcomes]
    succ = outcomes == num_stages[None, :] - 1
    t = np.cumsum(d[:, order], axis=1)
    cnt = succ.sum(axis=1)
    vals = np.where(
        cnt > 0, (t * succ[:, order]).sum(axis=1) / np.maximum(cnt, 1), 0.0
    )
    sigma = float(vals.std(ddof=1) / np.sqrt(ctrl_samples))
    z = abs(est - exact) / sigma
    assert z <= 3.0, f"streamed MC outside 3-sigma CLT bound: z={z}"
    control = {
        "k_combos": int(evaluator.exact_combination_count(ctrl_jobs)),
        "n_samples": ctrl_samples, "exact": float(exact),
        "streamed_est": float(est), "sigma": sigma, "z_score": float(z),
    }

    # --- throughput: K > MAX_EXACT_COMBOS, MC is the only option ---------
    n = 27  # M=2 -> K = 2**27 > MAX_EXACT_COMBOS
    jobs = generate_workload(rng, n)
    orders = policies.rank_order(jobs)[None]
    s_streamed = 1 << (12 if smoke else 23)
    s_materialized = 1 << (10 if smoke else 21)
    repeats = 1 if smoke else (3 if full else 2)

    def streamed_time():
        ts = []
        for rep in range(repeats + 1):  # first rep warms the jit cache
            t0 = time.perf_counter()
            evaluator.expected_sojourn_static(
                jobs, orders, samples=(seed + rep, s_streamed), impl=impl
            )
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts[1:]))

    def materialized_time():
        g = np.random.default_rng(seed)
        ts = []
        for _ in range(repeats + 1):
            t0 = time.perf_counter()
            # per-call work in the materialized design: host sampling of
            # the (S, N) table, then the explicit-outcomes op
            mc_o, mc_w = evaluator.sample_outcomes(jobs, s_materialized, g)
            evaluator.expected_sojourn_static(
                jobs, orders, outcomes=mc_o, weights=mc_w, impl=impl
            )
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts[1:]))

    t_streamed = streamed_time()
    t_materialized = materialized_time()
    tp_streamed = s_streamed / t_streamed
    tp_materialized = s_materialized / t_materialized
    row = {
        "k_combos": 1 << n, "n_jobs": n,
        "streamed_samples": s_streamed, "streamed_s": t_streamed,
        "streamed_samples_per_s": tp_streamed,
        "materialized_samples": s_materialized, "materialized_s": t_materialized,
        "materialized_samples_per_s": tp_materialized,
        "throughput_ratio": tp_streamed / tp_materialized,
    }
    if not smoke:
        assert row["throughput_ratio"] >= 2.0, (
            f"streamed MC below the 2x throughput bar: {row}"
        )
    _save("BENCH_eval_mc", {
        "mode": "smoke" if smoke else ("full" if full else "default"),
        "impl": impl,
        "clt_control": control,
        "rows": [row],
    })
    return [{**row, "control_z_score": control["z_score"]}]


# ---------------------------------------------------------------------------
# Roofline aggregation (reads dry-run artifacts)
# ---------------------------------------------------------------------------


def table_roofline():
    from repro.launch.roofline import RooflineReport

    paths = sorted(glob.glob("artifacts/dryrun/*.json"))
    if not paths:
        print("  (no dry-run artifacts; run `python -m repro.launch.dryrun` first)")
        return []
    report = RooflineReport.load(paths)
    print(report.to_markdown())
    _save("table_roofline", report.rows)
    return report.rows


# ---------------------------------------------------------------------------


def _fmt(rows: list[dict]) -> str:
    if not rows:
        return "  (empty)"
    keys = list(rows[0].keys())
    head = "| " + " | ".join(keys) + " |"
    sep = "|" + "---|" * len(keys)
    body = []
    for r in rows:
        body.append(
            "| " + " | ".join(
                f"{r[k]:.4g}" if isinstance(r[k], float) else str(r[k]) for k in keys
            ) + " |"
        )
    return "\n".join([head, sep] + body)


TABLES = {
    "fig1": fig1_objective_gap,
    "sojourn": table_sojourn,
    "competitive": table_competitive,
    "stages": table_stages,
    "trace": table_trace,
    "faults": table_faults,
    "eval_perf": table_eval_perf,
    "eval_dynamic": table_eval_dynamic,
    "eval_mc": table_eval_mc,
    "roofline": lambda full=False: table_roofline(),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--table", default="all", choices=["all", *TABLES])
    ap.add_argument("--full", action="store_true", help="paper-scale trials")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sample counts + interpret-mode kernels "
                         "(eval_mc only; CI crash canary)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persist the workload-keyed memo tier in DIR "
                         "(overrides REPRO_CACHE_DIR)")
    args = ap.parse_args()

    if args.cache_dir:
        from repro.core import policies as _policies

        os.environ["REPRO_CACHE_DIR"] = args.cache_dir
        print(f"workload cache dir: {_policies.ensure_cache_dir()}")
    elif args.full:
        # Paper-scale sweeps revisit the same workloads across tables and
        # reruns: persist the workload-keyed memo tier unless the user
        # already pointed REPRO_CACHE_DIR somewhere.
        from repro.core import policies as _policies

        print(f"workload cache dir: {_policies.ensure_cache_dir()}")

    names = list(TABLES) if args.table == "all" else [args.table]
    shared_study = None
    for name in names:
        t0 = time.perf_counter()
        if name in ("sojourn", "competitive") and args.table == "all":
            if shared_study is None:
                shared_study = _numerical_study(args.full)
            rows = TABLES[name](args.full, study=shared_study)
        elif name == "eval_mc":
            rows = table_eval_mc(full=args.full, smoke=args.smoke)
        else:
            rows = TABLES[name](full=args.full)
        dt = time.perf_counter() - t0
        print(f"\n## {name}  ({dt:.1f}s)")
        if name != "roofline":  # roofline prints its own markdown
            print(_fmt(rows))

    from repro.core import policies

    stats = policies.cache_stats()
    print(
        f"\nworkload cache: {stats['hits']} hits / {stats['misses']} misses "
        f"(hit rate {stats['hit_rate']:.1%}, {stats['entries']} entries)"
    )


if __name__ == "__main__":
    main()
